//! Fast Walsh–Hadamard transform — O(n log n) in-place butterflies.
//!
//! The native analogue of the L1 Pallas kernels (`kernels/walsh.py`);
//! used by the analysis layer, the native quantization pipeline, and
//! the `transform_perf` bench that quantifies the paper's "for free"
//! claim (butterfly vs dense-matmul rotation cost).

use super::is_pow2;

/// In-place orthonormal FWHT over `x` (natural/Hadamard ordering).
/// Equivalent to `x @ hadamard(n)` for the symmetric Sylvester matrix.
pub fn fwht(x: &mut [f64]) {
    let n = x.len();
    assert!(is_pow2(n), "FWHT length must be a power of two, got {n}");
    let mut h = 1;
    while h < n {
        for start in (0..n).step_by(2 * h) {
            for i in start..start + h {
                let a = x[i];
                let b = x[i + h];
                x[i] = a + b;
                x[i + h] = a - b;
            }
        }
        h *= 2;
    }
    let scale = 1.0 / (n as f64).sqrt();
    for v in x.iter_mut() {
        *v *= scale;
    }
}

/// Block-diagonal FWHT: transform each contiguous `group`-span
/// independently — `x @ (I ⊗ H_G)`, the GSR/local fast path.
pub fn grouped_fwht(x: &mut [f64], group: usize) {
    assert_eq!(x.len() % group, 0, "group must divide length");
    for chunk in x.chunks_mut(group) {
        fwht(chunk);
    }
}

/// FWHT over each row of a row-major `[rows, n]` batch.
pub fn fwht_batch(data: &mut [f64], n: usize) {
    assert_eq!(data.len() % n, 0);
    for row in data.chunks_mut(n) {
        fwht(row);
    }
}

/// Grouped FWHT over each row of a row-major `[rows, n]` batch.
pub fn grouped_fwht_batch(data: &mut [f64], n: usize, group: usize) {
    assert_eq!(data.len() % n, 0);
    for row in data.chunks_mut(n) {
        grouped_fwht(row, group);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::SplitMix64;
    use crate::transform::{block_diag, hadamard};

    #[test]
    fn matches_dense_hadamard() {
        let n = 64;
        let h = hadamard(n);
        let mut rng = SplitMix64::new(3);
        let x: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let dense = h.apply_right(&x);
        let mut fast = x.clone();
        fwht(&mut fast);
        for (a, b) in fast.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn grouped_matches_blockdiag_dense() {
        let n = 64;
        let g = 16;
        let bd = block_diag(&hadamard(g), n);
        let mut rng = SplitMix64::new(4);
        let x: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let dense = bd.apply_right(&x);
        let mut fast = x.clone();
        grouped_fwht(&mut fast, g);
        for (a, b) in fast.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn involution() {
        // Orthonormal FWHT is its own inverse (H symmetric, H² = I).
        let mut rng = SplitMix64::new(5);
        let x: Vec<f64> = (0..128).map(|_| rng.next_normal()).collect();
        let mut y = x.clone();
        fwht(&mut y);
        fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-10);
        }
    }

    #[test]
    fn preserves_l2_norm() {
        let mut rng = SplitMix64::new(6);
        let x: Vec<f64> = (0..256).map(|_| rng.next_normal()).collect();
        let n0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x;
        fwht(&mut y);
        let n1: f64 = y.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-8 * n0);
    }
}
