//! Sequency math (paper §2.1, Eq. 2).
//!
//! *Sequency* is the number of sign flips along a row of a ±1 matrix —
//! the Walsh-domain analogue of frequency. The Walsh matrix arranges
//! rows in ascending sequency; the Sylvester Hadamard matrix is in
//! "natural" order whose per-row sequency follows the bit-reversal +
//! Gray-code relation (see `sequency_of_natural_row`).

use super::Mat;

/// Sequency (sign-flip count) of row `i` of the size-`n` natural-ordered
/// Sylvester Hadamard matrix: bit-reverse over log₂(n) bits, then
/// Gray-to-binary decode (Tam & Goulet 1972). For n=8 the rows have
/// sequencies 0,7,3,4,1,6,2,5 — the paper's §2.1 example.
///
/// (The paper's Eq. 2 as printed — `bit_count(i ⊕ (i >> 1))` — is the
/// binary-to-Gray popcount and does not reproduce that example; this is
/// the construction that does, verified against directly-counted sign
/// flips in tests.)
pub fn sequency_of_natural_row(i: usize, n: usize) -> u32 {
    debug_assert!(n.is_power_of_two());
    let bits = n.trailing_zeros();
    let rev = if bits == 0 { 0 } else { i.reverse_bits() >> (usize::BITS - bits) };
    // Gray → binary: prefix XOR of all more-significant bits.
    let mut b = rev;
    let mut shift = 1;
    while (rev >> shift) != 0 {
        b ^= rev >> shift;
        shift += 1;
    }
    b as u32
}

/// Sequency measured directly: count sign changes along a row.
pub fn sequency_of_row(row: &[f64]) -> u32 {
    row.windows(2)
        .filter(|w| (w[0] >= 0.0) != (w[1] >= 0.0))
        .count() as u32
}

/// Permutation `p` such that `walsh(n) = hadamard(n)[p]` — natural rows
/// sorted by ascending sequency. Sequencies of a size-n Sylvester matrix
/// are a permutation of `0..n`, so the sort key is unique and this
/// equals the classical bit-reversal + Gray-code construction
/// (Tam & Goulet 1972).
pub fn walsh_permutation(n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by_key(|&i| sequency_of_natural_row(i, n));
    idx
}

/// Per-column-group sequency variance of a rotation matrix — the
/// quantity the paper's §3.2 argument says the Walsh ordering minimizes.
/// Groups span `group` consecutive columns; returns one variance per
/// group (of the sequencies of the rows... see `analysis::sequency` for
/// the full treatment; this helper measures a row-range of the matrix).
pub fn group_sequency_variance(m: &Mat, group: usize) -> Vec<f64> {
    assert_eq!(m.rows % group, 0);
    (0..m.rows / group)
        .map(|g| {
            let seqs: Vec<f64> = (g * group..(g + 1) * group)
                .map(|r| sequency_of_row(m.row(r)) as f64)
                .collect();
            let mean = seqs.iter().sum::<f64>() / seqs.len() as f64;
            seqs.iter().map(|s| (s - mean).powi(2)).sum::<f64>() / seqs.len() as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::hadamard;

    #[test]
    fn paper_example_n8() {
        // Paper §2.1: "the rows of a Hadamard matrix of size 8 have
        // 0, 7, 3, 4, 1, 6, 2, and 5 sequency values."
        let expect = [0, 7, 3, 4, 1, 6, 2, 5];
        for (i, &e) in expect.iter().enumerate() {
            assert_eq!(sequency_of_natural_row(i, 8), e);
        }
    }

    #[test]
    fn closed_form_matches_measured() {
        let h = hadamard(64);
        for i in 0..64 {
            assert_eq!(
                sequency_of_natural_row(i, 64),
                sequency_of_row(h.row(i)),
                "row {i}"
            );
        }
    }

    #[test]
    fn permutation_is_bijection_and_sorts() {
        for &n in &[2usize, 8, 64, 256] {
            let p = walsh_permutation(n);
            let mut seen = vec![false; n];
            for &i in &p {
                assert!(!seen[i]);
                seen[i] = true;
            }
            for w in p.windows(2) {
                assert!(
                    sequency_of_natural_row(w[0], n) < sequency_of_natural_row(w[1], n)
                );
            }
        }
    }

    #[test]
    fn sequencies_are_complete_range() {
        let n = 128;
        let mut seqs: Vec<u32> = (0..n).map(|i| sequency_of_natural_row(i, n)).collect();
        seqs.sort_unstable();
        let expect: Vec<u32> = (0..n as u32).collect();
        assert_eq!(seqs, expect);
    }
}
