//! Local (block-diagonal) rotations and the paper's R1 variant builder.

use super::{rht, walsh, Mat};
use crate::rng::SplitMix64;

/// The four R1 configurations compared in Table 1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum R1Kind {
    /// Global randomized Hadamard (QuaRot default).
    GH,
    /// Global Walsh — sequency-ordered, not randomized (paper §4).
    GW,
    /// Local randomized Hadamard, block = group size.
    LH,
    /// Grouped Sequency-arranged Rotation — block-diagonal Walsh
    /// (the paper's contribution, Eq. 3).
    GSR,
}

impl R1Kind {
    pub const ALL: [R1Kind; 4] = [R1Kind::GH, R1Kind::GW, R1Kind::LH, R1Kind::GSR];

    pub fn as_str(&self) -> &'static str {
        match self {
            R1Kind::GH => "GH",
            R1Kind::GW => "GW",
            R1Kind::LH => "LH",
            R1Kind::GSR => "GSR",
        }
    }

    pub fn parse(s: &str) -> Option<R1Kind> {
        match s.to_ascii_uppercase().as_str() {
            "GH" => Some(R1Kind::GH),
            "GW" => Some(R1Kind::GW),
            "LH" => Some(R1Kind::LH),
            "GSR" => Some(R1Kind::GSR),
            _ => None,
        }
    }

    /// Is this a local (block-diagonal) rotation?
    pub fn is_local(&self) -> bool {
        matches!(self, R1Kind::LH | R1Kind::GSR)
    }
}

impl std::fmt::Display for R1Kind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// `I_{n/G} ⊗ block` — the paper's Eq. 3 structure.
pub fn block_diag(block: &Mat, n: usize) -> Mat {
    let g = block.rows;
    assert_eq!(block.rows, block.cols, "block must be square");
    assert_eq!(n % g, 0, "group size {g} must divide dimension {n}");
    let mut out = Mat::zeros(n, n);
    for b in 0..n / g {
        for r in 0..g {
            for c in 0..g {
                out[(b * g + r, b * g + c)] = block[(r, c)];
            }
        }
    }
    out
}

/// Build an R1 rotation of size `n` with quantization group `group`.
pub fn build_r1(kind: R1Kind, n: usize, group: usize, rng: &mut SplitMix64) -> Mat {
    match kind {
        R1Kind::GH => rht(n, rng),
        R1Kind::GW => walsh(n),
        R1Kind::LH => block_diag(&rht(group, rng), n),
        R1Kind::GSR => block_diag(&walsh(group), n),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_diag_structure() {
        let b = walsh(4);
        let m = block_diag(&b, 12);
        // Off-block entries are exactly zero.
        for r in 0..12 {
            for c in 0..12 {
                if r / 4 != c / 4 {
                    assert_eq!(m[(r, c)], 0.0);
                } else {
                    assert_eq!(m[(r, c)], b[(r % 4, c % 4)]);
                }
            }
        }
    }

    #[test]
    fn all_r1_kinds_orthonormal() {
        for kind in R1Kind::ALL {
            let mut rng = SplitMix64::new(5);
            let m = build_r1(kind, 256, 64, &mut rng);
            assert!(
                m.orthogonality_defect() < 1e-9,
                "{kind} defect {}",
                m.orthogonality_defect()
            );
        }
    }

    #[test]
    fn locality_flag() {
        assert!(!R1Kind::GH.is_local());
        assert!(!R1Kind::GW.is_local());
        assert!(R1Kind::LH.is_local());
        assert!(R1Kind::GSR.is_local());
    }

    #[test]
    fn parse_roundtrip() {
        for kind in R1Kind::ALL {
            assert_eq!(R1Kind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(R1Kind::parse("nope"), None);
    }
}
