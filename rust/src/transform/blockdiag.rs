//! Local (block-diagonal) rotations and the paper's R1 variant builder.

use super::{is_pow2, rht, try_walsh, Mat};
use crate::rng::SplitMix64;

/// The four R1 configurations compared in Table 1, plus the two
/// parametric (angle-searched) families from the expanded grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum R1Kind {
    /// Global randomized Hadamard (QuaRot default).
    GH,
    /// Global Walsh — sequency-ordered, not randomized (paper §4).
    GW,
    /// Local randomized Hadamard, block = group size.
    LH,
    /// Grouped Sequency-arranged Rotation — block-diagonal Walsh
    /// (the paper's contribution, Eq. 3).
    GSR,
    /// Block-diagonal Givens chain: brick-wall stages of pairwise
    /// rotations with per-stage searched angles (ParoQuant-style).
    GIV,
    /// Block-diagonal butterfly factorization: log₂(block) stages of
    /// 2×2 orthogonal blocks with per-stage searched angles
    /// (ButterflyQuant-style).
    BFLY,
}

impl R1Kind {
    /// The paper's original four kinds. Analysis tables and Figure 1
    /// style comparisons stay scoped to these.
    pub const ALL: [R1Kind; 4] = [R1Kind::GH, R1Kind::GW, R1Kind::LH, R1Kind::GSR];

    /// Every candidate kind the search grid knows, including the
    /// parametric families.
    pub const EXTENDED: [R1Kind; 6] = [
        R1Kind::GH,
        R1Kind::GW,
        R1Kind::LH,
        R1Kind::GSR,
        R1Kind::GIV,
        R1Kind::BFLY,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            R1Kind::GH => "GH",
            R1Kind::GW => "GW",
            R1Kind::LH => "LH",
            R1Kind::GSR => "GSR",
            R1Kind::GIV => "GIV",
            R1Kind::BFLY => "BFLY",
        }
    }

    pub fn parse(s: &str) -> Option<R1Kind> {
        match s.to_ascii_uppercase().as_str() {
            "GH" => Some(R1Kind::GH),
            "GW" => Some(R1Kind::GW),
            "LH" => Some(R1Kind::LH),
            "GSR" => Some(R1Kind::GSR),
            "GIV" => Some(R1Kind::GIV),
            "BFLY" => Some(R1Kind::BFLY),
            _ => None,
        }
    }

    /// Is this a local (block-diagonal) rotation?
    pub fn is_local(&self) -> bool {
        matches!(self, R1Kind::LH | R1Kind::GSR | R1Kind::GIV | R1Kind::BFLY)
    }

    /// Does this kind carry searchable per-stage angles
    /// (`RotationSpec::r1_angles`)?
    pub fn is_parametric(&self) -> bool {
        matches!(self, R1Kind::GIV | R1Kind::BFLY)
    }
}

impl std::fmt::Display for R1Kind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Fallible `I_{n/G} ⊗ block` constructor (see [`block_diag`]).
pub fn try_block_diag(block: &Mat, n: usize) -> Result<Mat, String> {
    let g = block.rows;
    if block.rows != block.cols {
        return Err(format!("block must be square, got {}×{}", block.rows, block.cols));
    }
    if g == 0 || n % g != 0 {
        return Err(format!("block size {g} must divide dimension {n}"));
    }
    let mut out = Mat::zeros(n, n);
    for b in 0..n / g {
        for r in 0..g {
            for c in 0..g {
                out[(b * g + r, b * g + c)] = block[(r, c)];
            }
        }
    }
    Ok(out)
}

/// `I_{n/G} ⊗ block` — the paper's Eq. 3 structure. Panics on invalid
/// geometry; use [`try_block_diag`] where the sizes are untrusted.
pub fn block_diag(block: &Mat, n: usize) -> Mat {
    try_block_diag(block, n).unwrap_or_else(|e| panic!("{e}"))
}

fn validate_block(n: usize, block: usize) -> Result<(), String> {
    if !is_pow2(block) {
        return Err(format!("rotation block size must be a power of two, got {block}"));
    }
    if block > n || n % block != 0 {
        return Err(format!("rotation block size {block} must divide dimension {n}"));
    }
    Ok(())
}

/// Fallible R1 builder with an explicit local-rotation `block` size,
/// decoupled from the quantization group. Global kinds (GH/GW) ignore
/// `block` and validate `n` instead. This is the entry point the
/// `gsr search` candidate grid uses: invalid (kind, n, block)
/// combinations come back as `Err` early, never as a deep panic.
pub fn try_build_r1(
    kind: R1Kind,
    n: usize,
    block: usize,
    rng: &mut SplitMix64,
) -> Result<Mat, String> {
    match kind {
        R1Kind::GH => {
            if !is_pow2(n) {
                return Err(format!("global rotation needs power-of-two dimension, got {n}"));
            }
            Ok(rht(n, rng))
        }
        R1Kind::GW => try_walsh(n),
        R1Kind::LH => {
            validate_block(n, block)?;
            try_block_diag(&rht(block, rng), n)
        }
        R1Kind::GSR => {
            validate_block(n, block)?;
            try_block_diag(&try_walsh(block)?, n)
        }
        // Parametric kinds at their all-π/4 initialization; searched
        // angles flow through `try_build_parametric` directly (the
        // plan builder passes `RotationSpec::r1_angles`).
        R1Kind::GIV | R1Kind::BFLY => {
            let angles = super::parametric::default_angles(kind, block);
            super::parametric::try_build_parametric(kind, n, block, angles)
        }
    }
}

/// Build an R1 rotation of size `n` with local block = quantization
/// group `group` (the paper's fixed configuration). Panics on invalid
/// geometry; use [`try_build_r1`] for searched/untrusted block sizes.
pub fn build_r1(kind: R1Kind, n: usize, group: usize, rng: &mut SplitMix64) -> Mat {
    try_build_r1(kind, n, group, rng).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transform::walsh;

    #[test]
    fn block_diag_structure() {
        let b = walsh(4);
        let m = block_diag(&b, 12);
        // Off-block entries are exactly zero.
        for r in 0..12 {
            for c in 0..12 {
                if r / 4 != c / 4 {
                    assert_eq!(m[(r, c)], 0.0);
                } else {
                    assert_eq!(m[(r, c)], b[(r % 4, c % 4)]);
                }
            }
        }
    }

    #[test]
    fn all_r1_kinds_orthonormal() {
        for kind in R1Kind::ALL {
            let mut rng = SplitMix64::new(5);
            let m = build_r1(kind, 256, 64, &mut rng);
            assert!(
                m.orthogonality_defect() < 1e-9,
                "{kind} defect {}",
                m.orthogonality_defect()
            );
        }
    }

    #[test]
    fn locality_flag() {
        assert!(!R1Kind::GH.is_local());
        assert!(!R1Kind::GW.is_local());
        assert!(R1Kind::LH.is_local());
        assert!(R1Kind::GSR.is_local());
        assert!(R1Kind::GIV.is_local());
        assert!(R1Kind::BFLY.is_local());
    }

    #[test]
    fn parametric_flag() {
        for kind in R1Kind::EXTENDED {
            assert_eq!(kind.is_parametric(), matches!(kind, R1Kind::GIV | R1Kind::BFLY), "{kind}");
        }
    }

    #[test]
    fn parse_roundtrip() {
        for kind in R1Kind::EXTENDED {
            assert_eq!(R1Kind::parse(kind.as_str()), Some(kind));
        }
        assert_eq!(R1Kind::parse("nope"), None);
    }

    #[test]
    fn extended_kinds_orthonormal_at_default_angles() {
        for kind in [R1Kind::GIV, R1Kind::BFLY] {
            let mut rng = SplitMix64::new(5);
            let m = try_build_r1(kind, 256, 64, &mut rng).unwrap();
            assert!(m.orthogonality_defect() < 1e-12, "{kind}");
        }
    }

    #[test]
    fn try_build_r1_block_independent_of_group() {
        // The block size is a free knob for local kinds: same n, three
        // different blocks, all orthonormal, all block-diagonal.
        for block in [16usize, 32, 64] {
            let mut rng = SplitMix64::new(3);
            let m = try_build_r1(R1Kind::GSR, 128, block, &mut rng).unwrap();
            assert!(m.orthogonality_defect() < 1e-9);
            for r in 0..128 {
                for c in 0..128 {
                    if r / block != c / block {
                        assert_eq!(m[(r, c)], 0.0, "block={block} ({r},{c})");
                    }
                }
            }
        }
    }

    #[test]
    fn try_build_r1_rejects_bad_geometry_without_panicking() {
        let mut rng = SplitMix64::new(1);
        // Non-power-of-two block.
        let err = try_build_r1(R1Kind::GSR, 128, 24, &mut rng).unwrap_err();
        assert!(err.contains("power of two"), "{err}");
        // Power-of-two block that exceeds the dimension.
        assert!(try_build_r1(R1Kind::LH, 64, 128, &mut rng).is_err());
        // Global kind with a non-power-of-two dimension.
        assert!(try_build_r1(R1Kind::GW, 96, 32, &mut rng).is_err());
        assert!(try_build_r1(R1Kind::GH, 96, 32, &mut rng).is_err());
    }
}
