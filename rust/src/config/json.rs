//! Minimal JSON parser/serializer (manifest, meta and rotation-plan
//! files).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs beyond the
//! BMP; numbers parse as f64 (the manifest never exceeds 2⁵³).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::Path;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing garbage at byte {}", p.pos));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained; error message names the missing key.
    pub fn at(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- construction / serialization ----------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Parse a JSON document from a file (error names the path).
    pub fn from_file(path: &Path) -> Result<Json, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path:?}: {e}"))?;
        Json::parse(&text).map_err(|e| format!("{path:?}: {e}"))
    }

    /// Write pretty-printed JSON (with trailing newline) to a file.
    pub fn to_file(&self, path: &Path) -> Result<(), String> {
        let mut text = self.to_string_pretty();
        text.push('\n');
        std::fs::write(path, text).map_err(|e| format!("{path:?}: {e}"))
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    /// Write compact single-line JSON (JSONL-friendly: no newlines).
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&" ".repeat(indent + 1));
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for ch in s.chars() {
        match ch {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.pos)),
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|b| b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-')
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(b) = self.peek() {
                        if b == b'"' || b == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] found {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} found {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let src = r#"{
            "cfg": {"d_model": 256, "norm_eps": 1e-5},
            "variants": [{"name": "quarot_w2a16_gsr_r4gh", "sanity_ppl": 7.6}],
            "flag": true, "nothing": null, "neg": -3.25
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at("cfg").unwrap().at("d_model").unwrap().as_usize(), Some(256));
        assert_eq!(
            v.at("variants").unwrap().as_arr().unwrap()[0]
                .at("name")
                .unwrap()
                .as_str(),
            Some("quarot_w2a16_gsr_r4gh")
        );
        assert_eq!(v.at("neg").unwrap().as_f64(), Some(-3.25));
        assert_eq!(v.at("nothing").unwrap(), &Json::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a": [1, 2.5, "x\ny", {"b": false}], "c": null}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("123 45").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("line\n\"quoted\"\t\\".into());
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str(), Some("é"));
    }

    #[test]
    fn file_roundtrip() {
        let path = std::env::temp_dir()
            .join(format!("gsr_json_roundtrip_{}.json", std::process::id()));
        let v = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::arr_f64(&[1.0, 2.0, -0.25])),
            ("s", Json::str("plan")),
        ]);
        v.to_file(&path).unwrap();
        let re = Json::from_file(&path);
        let _ = std::fs::remove_file(&path);
        assert_eq!(re.unwrap(), v);
    }

    #[test]
    fn from_file_names_missing_path() {
        let err = Json::from_file(Path::new("/nonexistent/gsr_plan.json")).unwrap_err();
        assert!(err.contains("gsr_plan.json"), "{err}");
    }
}
