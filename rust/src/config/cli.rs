//! Tiny CLI argument parser for the `gsr` binary (no clap offline).
//!
//! Grammar: `gsr <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

/// Boolean flags of the `gsr` binary — everything else with a `--`
/// prefix takes a value (e.g. `--threads N`, `--plan FILE`). Keeping
/// this explicit removes the classic `--flag positional` ambiguity.
pub const KNOWN_FLAGS: [&str; 8] =
    ["verbose", "markdown", "all", "quick", "native", "force", "help", "synthetic"];

/// Parsed command line: subcommand, `--key value` options, bare flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: String,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut it = args.into_iter().peekable();
        let mut out = Args { subcommand: it.next().unwrap_or_default(), ..Default::default() };
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                // `--key=value`, known `--flag`, or `--key value`.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if KNOWN_FLAGS.contains(&key) {
                    out.flags.push(key.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    out.options.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(key.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.opt(key).unwrap_or(default)
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> usize {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--key 0.8`-style float option (sampling temperature, top-p).
    pub fn opt_f64(&self, key: &str, default: f64) -> f64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    /// `--seed N`-style u64 option (sampling seeds).
    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// `--threads N` with a documented default of the host's available
    /// parallelism: absent or `0` means one worker per available core.
    pub fn opt_threads(&self) -> usize {
        resolve_threads(self.opt_usize("threads", 0))
    }
}

/// Resolve a thread-count request: 0 means one worker per available
/// core (falling back to 1 if the host won't say). The single copy of
/// this policy — `Args::opt_threads` and the search planner both use it.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_options_flags() {
        let a = parse("eval --artifacts ../artifacts --windows 64 --verbose table1");
        assert_eq!(a.subcommand, "eval");
        assert_eq!(a.opt("artifacts"), Some("../artifacts"));
        assert_eq!(a.opt_usize("windows", 0), 64);
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["table1"]);
    }

    #[test]
    fn key_equals_value() {
        let a = parse("serve --port=9090");
        assert_eq!(a.opt("port"), Some("9090"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("bench --quick");
        assert!(a.has_flag("quick"));
        assert!(a.opt("quick").is_none());
    }

    #[test]
    fn defaults() {
        let a = parse("eval");
        assert_eq!(a.opt_or("artifacts", "artifacts"), "artifacts");
        assert_eq!(a.opt_usize("windows", 32), 32);
    }

    #[test]
    fn search_subcommand_grammar() {
        let a = parse(
            "search --blocks 32,64,128 --r1 GSR,GIV,BFLY --r4 GH --budget 12 \
             --threads 3 --proxy full --out plan.json --synthetic",
        );
        assert_eq!(a.subcommand, "search");
        assert_eq!(a.opt("blocks"), Some("32,64,128"));
        assert_eq!(a.opt("r1"), Some("GSR,GIV,BFLY"));
        assert_eq!(a.opt("proxy"), Some("full"));
        assert_eq!(a.opt("r4"), Some("GH"));
        assert_eq!(a.opt_usize("budget", 0), 12);
        assert_eq!(a.opt_threads(), 3);
        assert_eq!(a.opt("out"), Some("plan.json"));
        // `--synthetic` is a known flag: it must not swallow a value.
        assert!(a.has_flag("synthetic"));
        assert!(a.opt("synthetic").is_none());
    }

    #[test]
    fn calibrate_subcommand_grammar() {
        let a = parse(
            "calibrate --synthetic --seqs 16 --seq-len 48 --calib-seed 7 \
             --threads 2 --out hessians.bin",
        );
        assert_eq!(a.subcommand, "calibrate");
        assert!(a.has_flag("synthetic"));
        assert_eq!(a.opt_usize("seqs", 0), 16);
        assert_eq!(a.opt_usize("seq-len", 0), 48);
        assert_eq!(a.opt_usize("calib-seed", 0), 7);
        assert_eq!(a.opt("out"), Some("hessians.bin"));
        // `--calib FILE` on consumers is a valued option, not a flag.
        let b = parse("quantize-native --calib hessians.bin --bits 2");
        assert_eq!(b.opt("calib"), Some("hessians.bin"));
        assert_eq!(b.opt_usize("bits", 0), 2);
    }

    #[test]
    fn kernels_is_a_valued_option() {
        // `--kernels fast` must parse as a value, not swallow `fast`
        // into the flag list — the kernel-mode dispatch depends on it.
        let a = parse("serve --backend native --kernels fast --threads 2");
        assert_eq!(a.opt("kernels"), Some("fast"));
        assert!(!a.has_flag("kernels"));
        let b = parse("quantize-native --kernels reference");
        assert_eq!(b.opt("kernels"), Some("reference"));
    }

    #[test]
    fn sampling_and_paging_options_parse() {
        let a = parse(
            "generate --temperature 0.8 --top-k 40 --top-p 0.95 --seed 7 \
             --page-size 8 --kv-blocks 64 --prefill-chunk 16",
        );
        assert_eq!(a.opt_f64("temperature", 0.0), 0.8);
        assert_eq!(a.opt_usize("top-k", 0), 40);
        assert_eq!(a.opt_f64("top-p", 1.0), 0.95);
        assert_eq!(a.opt_u64("seed", 0), 7);
        assert_eq!(a.opt_usize("page-size", 16), 8);
        assert_eq!(a.opt_usize("kv-blocks", 0), 64);
        assert_eq!(a.opt_usize("prefill-chunk", 32), 16);
        // Absent or malformed values fall back to the default.
        let b = parse("generate --temperature warm");
        assert_eq!(b.opt_f64("temperature", 0.0), 0.0);
        assert_eq!(b.opt_u64("seed", 42), 42);
    }

    #[test]
    fn threads_default_is_available_parallelism() {
        let a = parse("search");
        assert!(a.opt_threads() >= 1);
        let b = parse("search --threads 0");
        assert!(b.opt_threads() >= 1);
    }
}
