//! Configuration: minimal JSON, CLI argument parsing, experiment settings.
//!
//! This image has no serde/clap offline, so the crate carries its own
//! small, well-tested JSON value model (`json`) and a declarative-enough
//! CLI layer (`cli`). Both are deliberately minimal — exactly what the
//! manifest format and the `gsr` binary need.

pub mod cli;
pub mod json;

pub use json::Json;
