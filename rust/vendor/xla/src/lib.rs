//! Offline stub of the `xla` PJRT bindings.
//!
//! The real dependency (PJRT CPU client over the XLA C API) cannot be
//! vendored into this offline image. This stub mirrors the exact API
//! surface `gsr::runtime` consumes so the whole workspace — library,
//! CLI, tests, benches — builds and runs without it. Every runtime
//! entry point fails fast with a clear error instead of crashing, and
//! callers that guard on artifact presence (tests, benches) skip
//! cleanly. Point the `xla` path dependency in `rust/Cargo.toml` at the
//! real crate to restore the hardware path; no `gsr` source changes are
//! needed.

use std::fmt;

/// Displayable error matching how `gsr::runtime` formats failures.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT is unavailable in this offline build (xla stub); \
         swap rust/vendor/xla for the real `xla` crate to enable the runtime path"
    ))
}

/// Element types uploadable to device buffers.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for u8 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// PJRT client handle. The stub cannot construct one, which keeps every
/// downstream method unreachable in practice (they still compile and
/// fail fast if reached).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }
}

/// Device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

/// Host-side literal (tensor) handle.
pub struct Literal;

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("to_tuple1"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable("to_vec"))
    }
}

/// Parsed HLO module handle.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// Computation wrapper accepted by `PjRtClient::compile`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub must not construct");
        let msg = err.to_string();
        assert!(msg.contains("offline"), "unhelpful stub error: {msg}");
    }
}
