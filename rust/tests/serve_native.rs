//! End-to-end tests of the native serving path: the unified `Backend`
//! trait, the batched multi-threaded native engine, and the coordinator
//! serving fp + quantized (heterogeneous searched-plan) variants with
//! no PJRT and no prebuilt artifacts.

use std::sync::Arc;
use std::time::Duration;

use gsr::coordinator::{BatchPolicy, Server};
use gsr::exec::{Backend, ExecPool, NativeBackend, NativeSet};
use gsr::model::{DenseModel, FpParams, ModelCfg, R4Kind};
use gsr::quant::{build_plan_rotations, quantize_native_plan, RotationPlan, RotationSpec};
use gsr::transform::R1Kind;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

/// A genuinely heterogeneous plan: layer 1 switches both R1 and R4, so
/// serving it exercises the per-layer basis change — the configuration
/// the PJRT/AOT path cannot represent.
fn hetero_plan(cfg: &ModelCfg, seed: u64) -> RotationPlan {
    RotationPlan {
        seed,
        layers: vec![
            RotationSpec { r1: R1Kind::GSR, r1_block: 8, r4: R4Kind::GH, r4_block: 64 },
            RotationSpec { r1: R1Kind::GH, r1_block: cfg.d_model, r4: R4Kind::LH, r4_block: 16 },
        ],
    }
}

fn fp_model(cfg: &ModelCfg, seed: u64) -> (FpParams, Arc<DenseModel>) {
    let fp = FpParams::synthetic(cfg, seed);
    let model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() });
    (fp, model)
}

fn searched_model(cfg: &ModelCfg, fp: &FpParams, seed: u64) -> Arc<DenseModel> {
    let rots = build_plan_rotations(cfg, &hetero_plan(cfg, seed)).unwrap();
    let (qp, _, _) = quantize_native_plan(fp, cfg, &rots, 2);
    Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None })
}

fn window(seed: usize, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 7 + seed * 13 + 1) % vocab) as i32).collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: logit {i} differs ({a} vs {b})");
    }
}

/// The acceptance property: batched native logits are bit-identical to
/// the serial `DenseModel::forward` for every batch composition and
/// every thread count — on fp *and* on a heterogeneous searched plan.
#[test]
fn batched_logits_bit_identical_for_any_batch_and_threads() {
    let cfg = tiny_cfg();
    let (fp, fp_m) = fp_model(&cfg, 11);
    let plan_m = searched_model(&cfg, &fp, 7);
    let s = 16;
    let seqs: Vec<Vec<i32>> = (0..4).map(|i| window(i, s, cfg.vocab)).collect();
    for model in [fp_m, plan_m] {
        let expect: Vec<Vec<f32>> = seqs.iter().map(|w| model.forward(w)).collect();
        for threads in [1, 3] {
            for batch in [1, 2, 4] {
                let backend = NativeBackend::new(Arc::clone(&model), batch, s, threads);
                let v = backend.vocab();
                for chunk in seqs.chunks(batch) {
                    // Pad under-full batches with zeros (a valid token).
                    let mut tokens = vec![0i32; batch * s];
                    for (i, w) in chunk.iter().enumerate() {
                        tokens[i * s..(i + 1) * s].copy_from_slice(w);
                    }
                    let out = backend.forward_batch(&tokens).unwrap();
                    for (i, w) in chunk.iter().enumerate() {
                        let row = &out[i * s * v..(i + 1) * s * v];
                        let idx = seqs.iter().position(|x| x == w).unwrap();
                        assert_bits_eq(
                            row,
                            &expect[idx],
                            &format!("{} b={batch} t={threads}", backend.name()),
                        );
                    }
                }
            }
        }
    }
}

/// Serve end to end: concurrent clients across fp + a heterogeneous
/// searched variant, logits bit-exact vs the direct forward, metrics
/// counters consistent.
#[test]
fn serve_native_end_to_end_with_concurrent_clients() {
    let cfg = tiny_cfg();
    let (fp, fp_m) = fp_model(&cfg, 11);
    let plan_m = searched_model(&cfg, &fp, 7);
    let (b, s) = (3, 20);
    let pool = Arc::new(ExecPool::new(3));
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::with_pool(Arc::clone(&fp_m), b, s, Arc::clone(&pool)));
    set.insert("searched", NativeBackend::with_pool(Arc::clone(&plan_m), b, s, pool));
    let policy = BatchPolicy { max_batch: b, max_wait: Duration::from_millis(2) };
    let server = Server::start_native(set, policy).expect("native server start");

    // Variable lengths exercise padding; expectations are the *direct*
    // serial forward on exactly the submitted tokens.
    let n_clients = 3;
    let per_client = 4;
    let mut cases: Vec<(String, Vec<i32>, Vec<f32>)> = Vec::new();
    for c in 0..n_clients {
        for r in 0..per_client {
            let (name, model) = if (c + r) % 2 == 0 {
                ("fp", &fp_m)
            } else {
                ("searched", &plan_m)
            };
            let len = s - (r % 3); // s, s-1, s-2
            let tokens = window(c * per_client + r, len, cfg.vocab);
            let expect = model.forward(&tokens);
            cases.push((name.to_string(), tokens, expect));
        }
    }
    std::thread::scope(|scope| {
        for (c, client_cases) in cases.chunks(per_client).enumerate() {
            let handle = server.handle();
            scope.spawn(move || {
                for (i, (variant, tokens, expect)) in client_cases.iter().enumerate() {
                    let logits = handle
                        .score(variant, tokens.clone())
                        .unwrap_or_else(|e| panic!("client {c} req {i}: {e}"));
                    assert_bits_eq(&logits, expect, &format!("client {c} req {i} ({variant})"));
                }
            });
        }
    });
    let total = (n_clients * per_client) as u64;
    let n_tokens: u64 = cases.iter().map(|(_, t, _)| t.len() as u64).sum();
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, total);
    assert_eq!(metrics.rejected, 0);
    assert_eq!(metrics.tokens, n_tokens);
    assert_eq!(
        metrics.batch_sizes.iter().sum::<usize>() as u64,
        total,
        "batch sizes must account for every request exactly once"
    );
    assert_eq!(metrics.batches as usize, metrics.batch_sizes.len());
    assert!(metrics.batches >= 1 && metrics.batches <= total);
    assert_eq!(metrics.request_latency.count(), total);
    assert_eq!(metrics.exec_latency.count(), metrics.batches);
}

/// Malformed requests are rejected individually with a clear error —
/// oversized sequences are never silently truncated, a bad token id
/// never fails the requests it was batched with, and the server keeps
/// serving afterwards.
#[test]
fn serve_native_rejects_malformed_requests() {
    let cfg = tiny_cfg();
    let (_, fp_m) = fp_model(&cfg, 5);
    let s = 12;
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 2, s, 2));
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let server = Server::start_native(set, policy).unwrap();
    let err = server
        .score("fp", window(1, s + 5, cfg.vocab))
        .expect_err("oversized request must be refused");
    assert!(err.contains("split the request"), "unhelpful error: {err}");
    // An out-of-vocab token is refused per-request, not per-batch: the
    // valid request submitted alongside it still gets its logits.
    let good = window(2, s, cfg.vocab);
    let mut bad = good.clone();
    bad[3] = cfg.vocab as i32; // == vocab → out of range
    let handle = server.handle();
    let (good_tx, good_rx) = std::sync::mpsc::channel();
    let (bad_tx, bad_rx) = std::sync::mpsc::channel();
    handle
        .submit(gsr::coordinator::Request {
            variant: "fp".into(),
            tokens: bad,
            reply: bad_tx,
        })
        .unwrap();
    handle
        .submit(gsr::coordinator::Request {
            variant: "fp".into(),
            tokens: good.clone(),
            reply: good_tx,
        })
        .unwrap();
    let bad_err = bad_rx.recv().unwrap().logits.expect_err("bad token must be refused");
    assert!(bad_err.contains("outside vocab"), "{bad_err}");
    let logits = good_rx.recv().unwrap().logits.expect("co-batched request must survive");
    assert_bits_eq(&logits, &fp_m.forward(&good), "co-batched request");
    // Unknown variants error without hanging and count as rejected.
    assert!(server.score("nope", vec![1, 2]).is_err());
    let metrics = server.shutdown();
    assert_eq!(metrics.rejected, 3, "oversized + bad token + unknown variant");
    assert_eq!(metrics.requests, 1, "only the good request completes");
}

/// The PPL engine through the batched backend agrees bit-for-bit with a
/// serial single-sequence reference — eval really did not change
/// numerics when it moved onto the batched execution layer.
#[test]
fn ppl_through_batched_backend_matches_serial_reference() {
    use gsr::eval::PplEngine;

    struct SerialRef {
        model: Arc<DenseModel>,
        batch: usize,
        seq: usize,
    }

    impl Backend for SerialRef {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn vocab(&self) -> usize {
            self.model.cfg().vocab
        }
        fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
            let rows = tokens.len() / self.seq;
            let mut out = Vec::new();
            for row in 0..rows {
                out.extend(self.model.forward(&tokens[row * self.seq..(row + 1) * self.seq]));
            }
            Ok(out)
        }
    }

    let cfg = tiny_cfg();
    let (_, model) = fp_model(&cfg, 9);
    let text: Vec<u8> = (0..600u32).map(|i| ((i * 11 + 3) % 64) as u8).collect();
    let (b, s) = (4, 24);
    let serial = SerialRef { model: Arc::clone(&model), batch: b, seq: s };
    let engine = PplEngine::new(0);
    let want = engine.evaluate(&serial, &text).unwrap();
    for threads in [1, 4] {
        let batched = NativeBackend::new(Arc::clone(&model), b, s, threads);
        let got = engine.evaluate(&batched, &text).unwrap();
        assert_eq!(got.ppl.to_bits(), want.ppl.to_bits(), "PPL drifted at {threads} threads");
        assert_eq!(got.tokens, want.tokens);
        assert_eq!(got.windows, want.windows);
    }
}
