//! End-to-end tests of the native serving path: the unified `Backend`
//! trait, the batched multi-threaded native engine, and the coordinator
//! serving fp + quantized (heterogeneous searched-plan) variants with
//! no PJRT and no prebuilt artifacts.

use std::sync::Arc;
use std::time::Duration;

use gsr::coordinator::{BatchPolicy, Server};
use gsr::exec::{Backend, ExecPool, NativeBackend, NativeSet};
use gsr::model::{DenseModel, FpParams, ModelCfg, R4Kind};
use gsr::quant::{build_plan_rotations, quantize_native_plan, RotationPlan, RotationSpec};
use gsr::sched::{SamplingParams, SchedConfig, SpecConfig};
use gsr::transform::R1Kind;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

/// A genuinely heterogeneous plan: layer 1 switches both R1 and R4, so
/// serving it exercises the per-layer basis change — the configuration
/// the PJRT/AOT path cannot represent.
fn hetero_plan(cfg: &ModelCfg, seed: u64) -> RotationPlan {
    RotationPlan {
        seed,
        layers: vec![
            RotationSpec {
                r1: R1Kind::GSR,
                r1_block: 8,
                r4: R4Kind::GH,
                r4_block: 64,
                r1_angles: 0,
            },
            RotationSpec {
                r1: R1Kind::GH,
                r1_block: cfg.d_model,
                r4: R4Kind::LH,
                r4_block: 16,
                r1_angles: 0,
            },
        ],
    }
}

fn fp_model(cfg: &ModelCfg, seed: u64) -> (FpParams, Arc<DenseModel>) {
    let fp = FpParams::synthetic(cfg, seed);
    let model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() });
    (fp, model)
}

fn searched_model(cfg: &ModelCfg, fp: &FpParams, seed: u64) -> Arc<DenseModel> {
    let rots = build_plan_rotations(cfg, &hetero_plan(cfg, seed)).unwrap();
    let (qp, _, _) = quantize_native_plan(fp, cfg, &rots, 2);
    Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None })
}

fn window(seed: usize, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 7 + seed * 13 + 1) % vocab) as i32).collect()
}

fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: logit {i} differs ({a} vs {b})");
    }
}

/// The acceptance property: batched native logits are bit-identical to
/// the serial `DenseModel::forward` for every batch composition and
/// every thread count — on fp *and* on a heterogeneous searched plan.
#[test]
fn batched_logits_bit_identical_for_any_batch_and_threads() {
    let cfg = tiny_cfg();
    let (fp, fp_m) = fp_model(&cfg, 11);
    let plan_m = searched_model(&cfg, &fp, 7);
    let s = 16;
    let seqs: Vec<Vec<i32>> = (0..4).map(|i| window(i, s, cfg.vocab)).collect();
    for model in [fp_m, plan_m] {
        let expect: Vec<Vec<f32>> = seqs.iter().map(|w| model.forward(w)).collect();
        for threads in [1, 3] {
            for batch in [1, 2, 4] {
                let backend = NativeBackend::new(Arc::clone(&model), batch, s, threads);
                let v = backend.vocab();
                for chunk in seqs.chunks(batch) {
                    // Pad under-full batches with zeros (a valid token).
                    let mut tokens = vec![0i32; batch * s];
                    for (i, w) in chunk.iter().enumerate() {
                        tokens[i * s..(i + 1) * s].copy_from_slice(w);
                    }
                    let out = backend.forward_batch(&tokens).unwrap();
                    for (i, w) in chunk.iter().enumerate() {
                        let row = &out[i * s * v..(i + 1) * s * v];
                        let idx = seqs.iter().position(|x| x == w).unwrap();
                        assert_bits_eq(
                            row,
                            &expect[idx],
                            &format!("{} b={batch} t={threads}", backend.name()),
                        );
                    }
                }
            }
        }
    }
}

/// Serve end to end: concurrent clients across fp + a heterogeneous
/// searched variant, logits bit-exact vs the direct forward, metrics
/// counters consistent.
#[test]
fn serve_native_end_to_end_with_concurrent_clients() {
    let cfg = tiny_cfg();
    let (fp, fp_m) = fp_model(&cfg, 11);
    let plan_m = searched_model(&cfg, &fp, 7);
    let (b, s) = (3, 20);
    let pool = Arc::new(ExecPool::new(3));
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::with_pool(Arc::clone(&fp_m), b, s, Arc::clone(&pool)));
    set.insert("searched", NativeBackend::with_pool(Arc::clone(&plan_m), b, s, pool));
    let policy = BatchPolicy { max_batch: b, max_wait: Duration::from_millis(2) };
    let server = Server::start_native(set, policy).expect("native server start");

    // Variable lengths exercise padding; expectations are the *direct*
    // serial forward on exactly the submitted tokens.
    let n_clients = 3;
    let per_client = 4;
    let mut cases: Vec<(String, Vec<i32>, Vec<f32>)> = Vec::new();
    for c in 0..n_clients {
        for r in 0..per_client {
            let (name, model) = if (c + r) % 2 == 0 {
                ("fp", &fp_m)
            } else {
                ("searched", &plan_m)
            };
            let len = s - (r % 3); // s, s-1, s-2
            let tokens = window(c * per_client + r, len, cfg.vocab);
            let expect = model.forward(&tokens);
            cases.push((name.to_string(), tokens, expect));
        }
    }
    std::thread::scope(|scope| {
        for (c, client_cases) in cases.chunks(per_client).enumerate() {
            let handle = server.handle();
            scope.spawn(move || {
                for (i, (variant, tokens, expect)) in client_cases.iter().enumerate() {
                    let logits = handle
                        .score(variant, tokens.clone())
                        .unwrap_or_else(|e| panic!("client {c} req {i}: {e}"));
                    assert_bits_eq(&logits, expect, &format!("client {c} req {i} ({variant})"));
                }
            });
        }
    });
    let total = (n_clients * per_client) as u64;
    let n_tokens: u64 = cases.iter().map(|(_, t, _)| t.len() as u64).sum();
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, total);
    assert_eq!(metrics.rejected, 0);
    assert_eq!(metrics.tokens, n_tokens);
    assert_eq!(
        metrics.batch_rows, total,
        "batch rows must account for every request exactly once"
    );
    assert!(metrics.batches >= 1 && metrics.batches <= total);
    assert_eq!(metrics.request_latency.count(), total);
    assert_eq!(metrics.exec_latency.count(), metrics.batches);
}

/// Malformed requests are rejected individually with a clear error —
/// oversized sequences are never silently truncated, a bad token id
/// never fails the requests it was batched with, and the server keeps
/// serving afterwards.
#[test]
fn serve_native_rejects_malformed_requests() {
    let cfg = tiny_cfg();
    let (_, fp_m) = fp_model(&cfg, 5);
    let s = 12;
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 2, s, 2));
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let server = Server::start_native(set, policy).unwrap();
    let err = server
        .score("fp", window(1, s + 5, cfg.vocab))
        .expect_err("oversized request must be refused");
    assert!(err.contains("split the request"), "unhelpful error: {err}");
    // An out-of-vocab token is refused per-request, not per-batch: the
    // valid request submitted alongside it still gets its logits.
    let good = window(2, s, cfg.vocab);
    let mut bad = good.clone();
    bad[3] = cfg.vocab as i32; // == vocab → out of range
    let handle = server.handle();
    let (good_tx, good_rx) = std::sync::mpsc::channel();
    let (bad_tx, bad_rx) = std::sync::mpsc::channel();
    handle
        .submit(gsr::coordinator::Request {
            variant: "fp".into(),
            tokens: bad,
            reply: bad_tx,
        })
        .unwrap();
    handle
        .submit(gsr::coordinator::Request {
            variant: "fp".into(),
            tokens: good.clone(),
            reply: good_tx,
        })
        .unwrap();
    let bad_err = bad_rx.recv().unwrap().logits.expect_err("bad token must be refused");
    assert!(bad_err.contains("outside vocab"), "{bad_err}");
    let logits = good_rx.recv().unwrap().logits.expect("co-batched request must survive");
    assert_bits_eq(&logits, &fp_m.forward(&good), "co-batched request");
    // Empty requests are refused instead of silently scoring padding.
    let empty_err = server.score("fp", vec![]).expect_err("empty request must be refused");
    assert!(empty_err.contains("at least one token"), "{empty_err}");
    // Unknown variants error without hanging and count as rejected.
    assert!(server.score("nope", vec![1, 2]).is_err());
    let metrics = server.shutdown();
    assert_eq!(metrics.rejected, 4, "oversized + bad token + empty + unknown variant");
    assert_eq!(metrics.rejected_too_long, 1);
    assert_eq!(metrics.rejected_bad_token, 1);
    assert_eq!(metrics.rejected_zero_length, 1);
    assert_eq!(metrics.rejected_unknown_variant, 1);
    assert_eq!(metrics.rejected_cache_pressure, 0);
    assert_eq!(metrics.requests, 1, "only the good request completes");
}

/// The PPL engine through the batched backend agrees bit-for-bit with a
/// serial single-sequence reference — eval really did not change
/// numerics when it moved onto the batched execution layer.
#[test]
fn ppl_through_batched_backend_matches_serial_reference() {
    use gsr::eval::PplEngine;

    struct SerialRef {
        model: Arc<DenseModel>,
        batch: usize,
        seq: usize,
    }

    impl Backend for SerialRef {
        fn batch(&self) -> usize {
            self.batch
        }
        fn seq(&self) -> usize {
            self.seq
        }
        fn vocab(&self) -> usize {
            self.model.cfg().vocab
        }
        fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
            let rows = tokens.len() / self.seq;
            let mut out = Vec::new();
            for row in 0..rows {
                out.extend(self.model.forward(&tokens[row * self.seq..(row + 1) * self.seq]));
            }
            Ok(out)
        }
    }

    let cfg = tiny_cfg();
    let (_, model) = fp_model(&cfg, 9);
    let text: Vec<u8> = (0..600u32).map(|i| ((i * 11 + 3) % 64) as u8).collect();
    let (b, s) = (4, 24);
    let serial = SerialRef { model: Arc::clone(&model), batch: b, seq: s };
    let engine = PplEngine::new(0);
    let want = engine.evaluate(&serial, &text).unwrap();
    for threads in [1, 4] {
        let batched = NativeBackend::new(Arc::clone(&model), b, s, threads);
        let got = engine.evaluate(&batched, &text).unwrap();
        assert_eq!(got.ppl.to_bits(), want.ppl.to_bits(), "PPL drifted at {threads} threads");
        assert_eq!(got.tokens, want.tokens);
        assert_eq!(got.windows, want.windows);
    }
}

/// Greedy reference decode by full re-forward: the semantics the
/// coordinator's KV-cached path must reproduce exactly. Returns the
/// emitted tokens and the number of decode rounds the sequence needs
/// (picks beyond the prefill pick).
fn greedy_reference(
    model: &DenseModel,
    prompt: &[i32],
    max_new: usize,
    stop: Option<i32>,
) -> (Vec<i32>, u64) {
    let v = model.cfg().vocab;
    let mut seq = prompt.to_vec();
    let mut out = Vec::new();
    let mut iters = 0u64;
    loop {
        iters += 1;
        let logits = model.forward(&seq);
        let tok = gsr::exec::greedy_argmax(&logits[(seq.len() - 1) * v..]);
        if stop == Some(tok) {
            break;
        }
        out.push(tok);
        if out.len() >= max_new {
            break;
        }
        seq.push(tok);
    }
    (out, iters - 1)
}

/// Generate end to end through the server: concurrent requests across
/// fp + a heterogeneous searched variant, batched decode rounds,
/// per-sequence completion (max_new and stop-token), and results equal
/// to a serial full-re-forward greedy reference — token for token.
#[test]
fn generate_native_end_to_end_matches_full_reforward_greedy() {
    let cfg = tiny_cfg();
    let (fp, fp_m) = fp_model(&cfg, 31);
    let plan_m = searched_model(&cfg, &fp, 13);
    let (b, s) = (3, 24);
    let pool = Arc::new(ExecPool::new(3));
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::with_pool(Arc::clone(&fp_m), b, s, Arc::clone(&pool)));
    set.insert("searched", NativeBackend::with_pool(Arc::clone(&plan_m), b, s, pool));
    let policy = BatchPolicy { max_batch: b, max_wait: Duration::from_millis(2) };
    let server = Server::start_native(set, policy).expect("native server start");

    // Build cases with references first (the stop case derives its stop
    // token from its own no-stop reference).
    struct Case {
        variant: &'static str,
        prompt: Vec<i32>,
        max_new: usize,
        stop: Option<i32>,
        want: Vec<i32>,
        rounds: u64,
    }
    let mut cases = Vec::new();
    for (i, &(variant, model, max_new)) in [
        ("fp", &fp_m, 5usize),
        ("searched", &plan_m, 3),
        ("fp", &fp_m, 6),
        ("searched", &plan_m, 6),
    ]
    .iter()
    .enumerate()
    {
        let prompt = window(40 + i, 6 + i % 3, cfg.vocab);
        let stop;
        let want;
        let rounds;
        if i == 2 {
            // Early-stop case: stop on the first token the no-stop
            // reference emits at an index whose prefix doesn't contain
            // it, so the expected cut is unambiguous.
            let (no_stop, _) = greedy_reference(model, &prompt, max_new, None);
            let j = (1..no_stop.len())
                .find(|&j| !no_stop[..j].contains(&no_stop[j]))
                .unwrap_or(0);
            stop = Some(no_stop[j]);
            let r = greedy_reference(model, &prompt, max_new, stop);
            want = r.0;
            rounds = r.1;
            assert_eq!(want, no_stop[..j].to_vec(), "stop must cut at index {j}");
        } else {
            stop = None;
            let r = greedy_reference(model, &prompt, max_new, None);
            want = r.0;
            rounds = r.1;
        }
        cases.push(Case { variant, prompt, max_new, stop, want, rounds });
    }

    // Submit everything up front so decode rounds batch across
    // sequences, then collect.
    let mut pending = Vec::new();
    for case in &cases {
        let (reply, rx) = std::sync::mpsc::channel();
        server
            .submit_generate(gsr::coordinator::GenerateRequest {
                variant: case.variant.to_string(),
                prompt: case.prompt.clone(),
                max_new: case.max_new,
                stop: case.stop,
                sampling: SamplingParams::greedy(),
                stream: None,
                reply,
            })
            .unwrap();
        pending.push(rx);
    }
    for (i, (case, rx)) in cases.iter().zip(pending).enumerate() {
        let got = rx.recv().unwrap().result.unwrap_or_else(|e| panic!("case {i}: {e}"));
        assert_eq!(got.tokens, case.want, "case {i} ({}) diverged from reference", case.variant);
        assert_eq!(got.prompt_len, case.prompt.len());
    }
    let metrics = server.shutdown();
    let total_emitted: u64 = cases.iter().map(|c| c.want.len() as u64).sum();
    let total_rounds: u64 = cases.iter().map(|c| c.rounds).sum();
    assert_eq!(metrics.generations, cases.len() as u64);
    assert_eq!(metrics.generation_failures, 0);
    assert_eq!(metrics.generated_tokens, total_emitted);
    assert_eq!(metrics.requests, cases.len() as u64, "generations count as requests");
    assert_eq!(metrics.rejected, 0);
    assert_eq!(metrics.decode_seqs, total_rounds, "every sequence-step accounted once");
    assert!(metrics.decode_steps >= 1 && metrics.decode_steps <= total_rounds);
    assert_eq!(metrics.decode_latency.count(), metrics.decode_steps);
    assert!(metrics.cache_tokens_peak >= 7, "peak occupancy covers prompt + decode");
    assert!(metrics.decode_tok_per_s() > 0.0);
}

/// `--kernels fast` decode parity: on the same quantized model, the
/// packed fast path emits greedy token sequences identical to the
/// reference kernels (argmax stability under the pinned logit bound),
/// for serial decoding and with intra-sequence sharding across pool
/// workers — and the backend advertises the fast label so metrics can
/// tell the modes apart.
#[test]
fn fast_kernels_greedy_sequences_match_reference() {
    use gsr::exec::greedy_argmax;
    use gsr::model::KernelMode;
    use gsr::quant::quantize_native_plan;

    let cfg = tiny_cfg();
    let (fp, _) = fp_model(&cfg, 19);
    let rots = build_plan_rotations(&cfg, &hetero_plan(&cfg, 9)).unwrap();
    let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
    let mut qpf = qp.clone();
    qpf.kernels = KernelMode::Fast;
    let reference = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None });
    let fast = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qpf, a_bits: None });
    let (s, max_new) = (24usize, 8usize);
    for threads in [1usize, 3] {
        let backend = NativeBackend::new(Arc::clone(&fast), 2, s, threads);
        assert_eq!(backend.name(), "native-quant-fast");
        for case in 0..3usize {
            let prompt = window(60 + case, 5 + case, cfg.vocab);
            let (want, _) = greedy_reference(&reference, &prompt, max_new, None);
            let (mut gen, last) = backend.start_generation(&prompt).unwrap();
            let mut got = vec![greedy_argmax(&last)];
            while got.len() < max_new {
                let logits = backend.decode(&mut gen, *got.last().unwrap()).unwrap();
                got.push(greedy_argmax(&logits));
            }
            assert_eq!(got, want, "case {case} t={threads}: fast greedy diverged");
        }
    }
}

/// Generation admission is against the variant's block pool: empty
/// prompts, zero budgets, bad token ids, unknown variants and budgets
/// beyond the pool's total token inventory are refused with clear
/// errors, counted per reason, and the server keeps serving — while a
/// peak that the old contiguous rule would refuse is now admitted.
#[test]
fn generate_rejects_invalid_requests() {
    let cfg = tiny_cfg();
    let (_, fp_m) = fp_model(&cfg, 3);
    let s = 10;
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 2, s, 2));
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let server = Server::start_native(set, policy).unwrap();
    // Default pool: 2 seqs × ceil(10/16) blocks of 16 tokens = 32
    // tokens total; a peak of 8 + 30 − 1 = 37 can never complete.
    let err = server
        .generate("fp", window(1, 8, cfg.vocab), 30, None)
        .expect_err("budget beyond the pool's token inventory must be refused");
    assert!(err.contains("kv cache slots"), "unhelpful error: {err}");
    assert!(err.contains("--kv-blocks"), "error should point at the knob: {err}");
    assert!(server.generate("fp", vec![], 3, None).is_err(), "empty prompt");
    assert!(server.generate("fp", vec![1, 2], 0, None).is_err(), "zero budget");
    assert!(server.generate("fp", vec![1, 64], 3, None).is_err(), "bad prompt token");
    assert!(server.generate("fp", vec![1, 2], 3, Some(-1)).is_err(), "bad stop token");
    assert!(server.generate("nope", vec![1, 2], 3, None).is_err(), "unknown variant");
    // A valid request still succeeds afterwards, and scoring coexists.
    let out = server.generate("fp", window(2, 4, cfg.vocab), 3, None).unwrap();
    assert_eq!(out.tokens.len(), 3);
    // Paged admission outlives the old contiguous rule: peak 8 + 5 − 1
    // = 12 exceeds the backend's 10-token contiguous cache but fits the
    // 32-token pool, so the request is admitted and decodes fully.
    let out = server.generate("fp", window(5, 8, cfg.vocab), 5, None).unwrap();
    assert_eq!(out.tokens.len(), 5, "beyond-contiguous budget must decode fully");
    assert!(server.score("fp", window(3, s, cfg.vocab)).is_ok());
    let metrics = server.shutdown();
    assert_eq!(metrics.rejected, 6);
    assert_eq!(metrics.rejected_cache_pressure, 1);
    assert_eq!(metrics.rejected_zero_length, 2, "empty prompt + zero budget");
    assert_eq!(metrics.rejected_bad_token, 2, "prompt token + stop token");
    assert_eq!(metrics.rejected_unknown_variant, 1);
    assert_eq!(metrics.generations, 2);
    assert_eq!(metrics.generation_failures, 0);
    assert_eq!(metrics.generated_tokens, 8);
}

/// The paged-admission acceptance case: every sequence's peak exceeds
/// the old contiguous rule (`prompt + max_new − 1 ≤ seq`, which would
/// have rejected all of them), their aggregate peak far exceeds the
/// block pool, and scoring traffic rides the same executor — yet every
/// sequence completes, preemption recomputes the youngest caches
/// instead of rejecting or deadlocking, and every completion still
/// matches the full-re-forward greedy reference token for token.
#[test]
fn paged_serving_completes_beyond_contiguous_capacity() {
    let cfg = tiny_cfg();
    let (_, fp_m) = fp_model(&cfg, 23);
    let s = 8;
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 4, s, 2));
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
    let sched = SchedConfig { page_size: 4, kv_blocks: 5, prefill_chunk: 3, speculate: None };
    let server = Server::start_native_sched(set, policy, sched).unwrap();
    // 3 sequences, each peaking at 4 + 8 − 1 = 11 cached tokens (> seq
    // = 8), with an aggregate peak of 33 against a 20-token pool.
    let cases: Vec<(Vec<i32>, Vec<i32>)> = (0..3)
        .map(|i| {
            let prompt = window(70 + i, 4, cfg.vocab);
            let (want, _) = greedy_reference(&fp_m, &prompt, 8, None);
            (prompt, want)
        })
        .collect();
    let mut pending = Vec::new();
    for (prompt, _) in &cases {
        let (reply, rx) = std::sync::mpsc::channel();
        server
            .submit_generate(gsr::coordinator::GenerateRequest {
                variant: "fp".to_string(),
                prompt: prompt.clone(),
                max_new: 8,
                stop: None,
                sampling: SamplingParams::greedy(),
                stream: None,
                reply,
            })
            .unwrap();
        pending.push(rx);
    }
    // Scoring traffic interleaves with the generation rounds.
    let score_tokens = window(77, s, cfg.vocab);
    let want_logits = fp_m.forward(&score_tokens);
    let logits = server.score("fp", score_tokens).unwrap();
    assert_bits_eq(&logits, &want_logits, "scoring co-exists with paged generation");
    for (i, ((_, want), rx)) in cases.iter().zip(pending).enumerate() {
        let got = rx.recv().unwrap().result.unwrap_or_else(|e| panic!("seq {i}: {e}"));
        assert_eq!(&got.tokens, want, "seq {i} diverged under paging/preemption");
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.generations, 3);
    assert_eq!(metrics.generation_failures, 0);
    assert_eq!(metrics.rejected, 0, "paged admission accepts what the pool can complete");
    assert_eq!(metrics.kv_blocks_total, 5);
    assert!(metrics.preemptions >= 1, "a contended pool must preempt");
    assert!(metrics.evicted_blocks >= metrics.preemptions, "a victim holds >= 1 block");
    assert!(metrics.recomputed_tokens >= 1, "preempted caches recompute on resume");
    let report = metrics.report(Duration::from_millis(50));
    let needles =
        ["paged: pool=", "preemptions=", "evicted_blocks=", "recomputed_tokens=", "step p50="];
    for needle in needles {
        assert!(report.contains(needle), "report missing {needle:?}:\n{report}");
    }
}

/// Sampled generations are replayable: the same request (prompt, seed,
/// sampling parameters) returns bit-identical tokens whether it runs
/// essentially alone or co-scheduled with contending sampled traffic —
/// the per-request RNG stream never observes round composition.
#[test]
fn sampled_generation_replays_bit_identically_under_different_co_load() {
    let cfg = tiny_cfg();
    let (_, fp_m) = fp_model(&cfg, 41);
    let (b, s) = (3, 16);
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), b, s, 2));
    let policy = BatchPolicy { max_batch: b, max_wait: Duration::from_millis(2) };
    let sched = SchedConfig { page_size: 4, kv_blocks: 12, prefill_chunk: 3, speculate: None };
    let server = Server::start_native_sched(set, policy, sched).unwrap();
    let prompt = window(80, 5, cfg.vocab);
    let params = SamplingParams { temperature: 0.9, top_k: 12, top_p: 0.95, seed: 1234 };
    // Quiet server: the request runs essentially alone.
    let alone = server.generate_with("fp", prompt.clone(), 8, None, params.clone()).unwrap();
    assert_eq!(alone.tokens.len(), 8);
    // Noisy server: co-scheduled sampled generations (different seeds)
    // contend for decode rounds and pool blocks.
    let mut noise = Vec::new();
    for i in 0..4usize {
        let (reply, rx) = std::sync::mpsc::channel();
        server
            .submit_generate(gsr::coordinator::GenerateRequest {
                variant: "fp".to_string(),
                prompt: window(90 + i, 4 + i, cfg.vocab),
                max_new: 6,
                stop: None,
                sampling: SamplingParams { seed: 7 + i as u64, ..params.clone() },
                stream: None,
                reply,
            })
            .unwrap();
        noise.push(rx);
    }
    let busy = server.generate_with("fp", prompt.clone(), 8, None, params.clone()).unwrap();
    for (i, rx) in noise.into_iter().enumerate() {
        rx.recv().unwrap().result.unwrap_or_else(|e| panic!("noise {i}: {e}"));
    }
    assert_eq!(busy.tokens, alone.tokens, "co-load must never change a seeded sample");
    let metrics = server.shutdown();
    assert_eq!(metrics.generations, 6);
    assert_eq!(metrics.generation_failures, 0);
}

/// Two-variant set for the speculative tests: the fp target plus a W2
/// searched-plan draft of the same checkpoint, sharing one exec pool.
fn spec_set(fp_m: &Arc<DenseModel>, plan_m: &Arc<DenseModel>, b: usize, s: usize) -> NativeSet {
    let pool = Arc::new(ExecPool::new(2));
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::with_pool(Arc::clone(fp_m), b, s, Arc::clone(&pool)));
    set.insert("q2", NativeBackend::with_pool(Arc::clone(plan_m), b, s, pool));
    set
}

/// The speculative acceptance property: with a W2 draft verifying
/// through the fp target, greedy *and* seeded-sampled generations —
/// including an early-stop case — are token-for-token identical to the
/// same requests on a non-speculative server, and requests targeting
/// the draft variant itself still decode plainly. Speculation changes
/// how many forwards run, never what is emitted.
#[test]
fn speculative_generation_matches_non_speculative_token_for_token() {
    let cfg = tiny_cfg();
    let (fp, fp_m) = fp_model(&cfg, 29);
    let plan_m = searched_model(&cfg, &fp, 11);
    let (b, s) = (3, 24);
    let sched = SchedConfig { page_size: 4, kv_blocks: 24, prefill_chunk: 3, speculate: None };
    let spec_sched = SchedConfig {
        speculate: Some(SpecConfig { draft: "q2".to_string(), k: 3 }),
        ..sched.clone()
    };
    let policy = || BatchPolicy { max_batch: b, max_wait: Duration::from_millis(2) };
    let baseline =
        Server::start_native_sched(spec_set(&fp_m, &plan_m, b, s), policy(), sched).unwrap();
    let spec =
        Server::start_native_sched(spec_set(&fp_m, &plan_m, b, s), policy(), spec_sched).unwrap();

    // Mixed traffic: greedy, two sampled seeds, a stop-token case, and
    // a request targeting the draft variant itself.
    let sampled = |seed: u64| SamplingParams { temperature: 0.9, top_k: 12, top_p: 0.95, seed };
    let stop = {
        let prompt = window(103, 5, cfg.vocab);
        let (no_stop, _) = greedy_reference(&fp_m, &prompt, 8, None);
        let j = (1..no_stop.len()).find(|&j| !no_stop[..j].contains(&no_stop[j])).unwrap_or(0);
        (prompt, Some(no_stop[j]))
    };
    let cases: Vec<(&str, Vec<i32>, usize, Option<i32>, SamplingParams)> = vec![
        ("fp", window(100, 5, cfg.vocab), 8, None, SamplingParams::greedy()),
        ("fp", window(101, 4, cfg.vocab), 8, None, sampled(7)),
        ("fp", window(102, 6, cfg.vocab), 6, None, sampled(91)),
        ("fp", stop.0, 8, stop.1, SamplingParams::greedy()),
        ("q2", window(104, 5, cfg.vocab), 6, None, sampled(3)),
    ];
    for (i, (variant, prompt, max_new, stop, sampling)) in cases.iter().enumerate() {
        let want = baseline
            .generate_with(variant, prompt.clone(), *max_new, *stop, sampling.clone())
            .unwrap_or_else(|e| panic!("baseline case {i}: {e}"));
        let got = spec
            .generate_with(variant, prompt.clone(), *max_new, *stop, sampling.clone())
            .unwrap_or_else(|e| panic!("speculative case {i}: {e}"));
        assert_eq!(
            got.tokens, want.tokens,
            "case {i} ({variant}): speculative decode changed the output"
        );
    }
    let base_metrics = baseline.shutdown();
    let metrics = spec.shutdown();
    assert_eq!(metrics.generations, cases.len() as u64);
    assert_eq!(metrics.generation_failures, 0);
    assert_eq!(metrics.generated_tokens, base_metrics.generated_tokens);
    assert!(metrics.spec_rounds >= 1, "fp-target requests must run draft/verify rounds");
    assert!(metrics.drafted_tokens >= metrics.accepted_draft_tokens);
    assert_eq!(
        metrics.rejected_draft_tokens,
        metrics.drafted_tokens - metrics.accepted_draft_tokens,
        "every drafted token is accepted or rejected, exactly once"
    );
    assert!(
        metrics.decode_emitted <= metrics.generated_tokens,
        "emitted accounting: decode emissions never exceed completed-generation tokens"
    );
    assert!(metrics.decode_tok_per_s() > 0.0);
    let report = metrics.report(Duration::from_millis(50));
    for needle in ["spec: rounds=", "acceptance=", "draft p50="] {
        assert!(report.contains(needle), "report missing {needle:?}:\n{report}");
    }
    assert_eq!(base_metrics.spec_rounds, 0);
    assert!(!base_metrics.report(Duration::from_millis(50)).contains("spec:"));
}

/// Speculation under block-pool pressure: concurrent speculative
/// sequences whose aggregate (target + draft) peak far exceeds the pool
/// force preemption of both caches — yet every sequence completes,
/// matching the greedy reference token for token.
#[test]
fn speculative_decoding_survives_preemption_of_both_caches() {
    let cfg = tiny_cfg();
    let (fp, fp_m) = fp_model(&cfg, 37);
    let plan_m = searched_model(&cfg, &fp, 19);
    // Peak per sequence: target ceil(11/4) + draft ceil(10/4) = 6
    // blocks; three sequences demand 18 against a 7-block pool.
    let sched = SchedConfig {
        page_size: 4,
        kv_blocks: 7,
        prefill_chunk: 3,
        speculate: Some(SpecConfig { draft: "q2".to_string(), k: 3 }),
    };
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
    let server =
        Server::start_native_sched(spec_set(&fp_m, &plan_m, 4, 16), policy, sched).unwrap();
    let cases: Vec<(Vec<i32>, Vec<i32>)> = (0..3)
        .map(|i| {
            let prompt = window(110 + i, 4, cfg.vocab);
            let (want, _) = greedy_reference(&fp_m, &prompt, 8, None);
            (prompt, want)
        })
        .collect();
    let mut pending = Vec::new();
    for (prompt, _) in &cases {
        let (reply, rx) = std::sync::mpsc::channel();
        server
            .submit_generate(gsr::coordinator::GenerateRequest {
                variant: "fp".to_string(),
                prompt: prompt.clone(),
                max_new: 8,
                stop: None,
                sampling: SamplingParams::greedy(),
                stream: None,
                reply,
            })
            .unwrap();
        pending.push(rx);
    }
    for (i, ((_, want), rx)) in cases.iter().zip(pending).enumerate() {
        let got = rx.recv().unwrap().result.unwrap_or_else(|e| panic!("seq {i}: {e}"));
        assert_eq!(&got.tokens, want, "seq {i} diverged under speculative preemption");
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.generations, 3);
    assert_eq!(metrics.generation_failures, 0);
    assert_eq!(metrics.rejected, 0, "each sequence fits the pool alone, so all admit");
    assert!(metrics.preemptions >= 1, "a contended pool must preempt");
    assert!(metrics.spec_rounds >= 1, "speculation must still run under pressure");
}

/// A `--speculate` that fails to resolve (draft variant not resident)
/// refuses every generation loudly instead of silently serving
/// non-speculative rounds; scoring is unaffected.
#[test]
fn speculate_unresolved_draft_rejects_generations_loudly() {
    let cfg = tiny_cfg();
    let (_, fp_m) = fp_model(&cfg, 43);
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 2, 16, 2));
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let sched = SchedConfig {
        speculate: Some(SpecConfig { draft: "nope".to_string(), k: 2 }),
        ..SchedConfig::default()
    };
    let server = Server::start_native_sched(set, policy, sched).unwrap();
    let err = server
        .generate("fp", window(1, 4, cfg.vocab), 3, None)
        .expect_err("unresolved speculation must refuse generations");
    assert!(err.contains("not resident"), "unhelpful error: {err}");
    assert!(err.contains("nope"), "error should name the draft variant: {err}");
    assert!(server.score("fp", window(2, 8, cfg.vocab)).is_ok(), "scoring is unaffected");
    let metrics = server.shutdown();
    assert_eq!(metrics.generations, 0);
    assert_eq!(metrics.rejected_unknown_variant, 1);
}

/// Streaming delivery: every emitted token arrives on the stream
/// channel at pick time, in order, and the final reply carries the
/// same sequence — which still matches the greedy reference.
#[test]
fn generate_stream_delivers_tokens_in_order() {
    let cfg = tiny_cfg();
    let (_, fp_m) = fp_model(&cfg, 17);
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 2, 16, 2));
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let server = Server::start_native(set, policy).unwrap();
    let handle = server.handle();
    let prompt = window(55, 6, cfg.vocab);
    let (stream, done) = handle
        .generate_stream("fp", prompt.clone(), 5, None, SamplingParams::greedy())
        .unwrap();
    let out = done.recv().unwrap().result.unwrap();
    let streamed: Vec<i32> = stream.iter().collect();
    assert_eq!(streamed, out.tokens, "stream must carry exactly the emitted tokens");
    let (want, _) = greedy_reference(&fp_m, &prompt, 5, None);
    assert_eq!(out.tokens, want);
    let metrics = server.shutdown();
    assert_eq!(metrics.generations, 1);
}
