//! Property-based tests over the native library invariants.
//!
//! proptest is not available in this offline image, so this file carries
//! a minimal in-repo property harness: deterministic SplitMix64-driven
//! case generation with failure reporting of the offending seed. Each
//! property runs across a seed sweep; a failing seed reproduces exactly.

use gsr::calib::HessianAccum;
use gsr::quant::{fake_quant_sym, gptq_quantize, pack2, rtn_quantize, unpack2};
use gsr::rng::SplitMix64;
use gsr::transform::{
    build_r1, fwht, grouped_fwht, hadamard, rht, walsh, walsh_permutation, Mat, R1Kind,
};

/// Run `prop` for `cases` deterministic seeds; panic names the seed.
fn for_seeds(cases: u64, prop: impl Fn(u64, &mut SplitMix64)) {
    for seed in 0..cases {
        let mut rng = SplitMix64::new(0xBEEF ^ (seed * 0x9E37_79B9));
        prop(seed, &mut rng);
    }
}

fn rand_pow2(rng: &mut SplitMix64, lo_log: u32, hi_log: u32) -> usize {
    1usize << (lo_log + rng.next_below((hi_log - lo_log + 1) as u64) as u32)
}

#[test]
fn prop_all_rotations_orthonormal() {
    for_seeds(24, |seed, rng| {
        let n = rand_pow2(rng, 3, 8);
        let group = rand_pow2(rng, 2, 3).min(n);
        for kind in R1Kind::ALL {
            let m = build_r1(kind, n, group, rng);
            let defect = m.orthogonality_defect();
            assert!(defect < 1e-9, "seed {seed} kind {kind} n {n} defect {defect}");
        }
    });
}

#[test]
fn prop_fwht_involution_and_norm() {
    for_seeds(32, |seed, rng| {
        let n = rand_pow2(rng, 1, 10);
        let x: Vec<f64> = (0..n).map(|_| rng.next_normal() * 3.0).collect();
        let norm0: f64 = x.iter().map(|v| v * v).sum();
        let mut y = x.clone();
        fwht(&mut y);
        let norm1: f64 = y.iter().map(|v| v * v).sum();
        assert!(
            (norm0 - norm1).abs() <= 1e-8 * norm0.max(1.0),
            "seed {seed}: norm not preserved"
        );
        fwht(&mut y);
        for (a, b) in x.iter().zip(&y) {
            assert!((a - b).abs() < 1e-8, "seed {seed}: not an involution");
        }
    });
}

#[test]
fn prop_grouped_fwht_equals_blockwise() {
    for_seeds(16, |seed, rng| {
        let g = rand_pow2(rng, 2, 5);
        let blocks = 1 + rng.next_below(6) as usize;
        let n = g * blocks;
        let x: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let mut fast = x.clone();
        grouped_fwht(&mut fast, g);
        for b in 0..blocks {
            let mut chunk = x[b * g..(b + 1) * g].to_vec();
            fwht(&mut chunk);
            for (i, v) in chunk.iter().enumerate() {
                assert!((fast[b * g + i] - v).abs() < 1e-10, "seed {seed}");
            }
        }
    });
}

#[test]
fn prop_walsh_is_row_permutation_of_hadamard() {
    for_seeds(6, |seed, rng| {
        let n = rand_pow2(rng, 1, 8);
        let h = hadamard(n);
        let w = walsh(n);
        let p = walsh_permutation(n);
        for (dst, &src) in p.iter().enumerate() {
            for c in 0..n {
                assert!(
                    (w[(dst, c)] - h[(src, c)]).abs() < 1e-12,
                    "seed {seed} n {n}"
                );
            }
        }
        let _ = rng.next_u64();
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    for_seeds(32, |seed, rng| {
        let c = 4 * (1 + rng.next_below(32) as usize);
        let h = 1 + rng.next_below(48) as usize;
        let codes: Vec<i32> = (0..c * h).map(|_| rng.next_below(4) as i32).collect();
        assert_eq!(unpack2(&pack2(&codes, c, h), c, h), codes, "seed {seed}");
    });
}

#[test]
fn prop_rtn_error_bound() {
    for_seeds(24, |seed, rng| {
        let group = rand_pow2(rng, 2, 4);
        let c = group * (1 + rng.next_below(4) as usize);
        let h = 1 + rng.next_below(12) as usize;
        let w = Mat::from_fn(c, h, |_, _| rng.next_normal() * 2.0);
        let q = rtn_quantize(&w, 4, group, false);
        let deq = q.dequant();
        for row in 0..c {
            let g = row / group;
            for col in 0..h {
                let step = q.scale[g * h + col];
                let err = (deq[(row, col)] - w[(row, col)]).abs();
                assert!(err <= 0.5 * step + 1e-9, "seed {seed} err {err} step {step}");
            }
        }
    });
}

#[test]
fn prop_fake_quant_on_grid_and_bounded() {
    for_seeds(24, |seed, rng| {
        let group = rand_pow2(rng, 2, 5);
        let n = group * (1 + rng.next_below(6) as usize);
        let bits = 2 + rng.next_below(4) as u32;
        let mut x: Vec<f64> = (0..n).map(|_| rng.next_normal() * 4.0).collect();
        let orig = x.clone();
        fake_quant_sym(&mut x, bits, group, 0.9);
        let levels = (1u32 << (bits - 1)) - 1;
        for (chunk, ochunk) in x.chunks(group).zip(orig.chunks(group)) {
            let absmax = ochunk.iter().fold(0.0f64, |m, &v| m.max(v.abs()));
            let scale = 0.9 * absmax / levels as f64;
            for &v in chunk {
                assert!(v.abs() <= absmax + 1e-9, "seed {seed}");
                if scale > 0.0 {
                    let q = v / scale;
                    assert!((q - q.round()).abs() < 1e-6, "seed {seed}: off-grid");
                }
            }
        }
    });
}

#[test]
fn prop_gptq_no_worse_than_rtn_hessian_loss() {
    // GPTQ minimizes tr(ΔWᵀ H ΔW); across random correlated Hessians it
    // must not lose to plain RTN (allowing numerical jitter).
    for_seeds(8, |seed, rng| {
        let c = 32;
        let h = 8;
        let group = 8;
        let w = Mat::from_fn(c, h, |_, _| rng.next_normal());
        // Correlated activations with outlier channels.
        let rows = 96;
        let mut x = vec![0.0; rows * c];
        for r in 0..rows {
            let base = rng.next_normal();
            for j in 0..c {
                let amp = if j % 11 == 0 { 6.0 } else { 1.0 };
                x[r * c + j] = amp * (0.5 * base + 0.5 * rng.next_normal());
            }
        }
        let mut hess = Mat::zeros(c, c);
        for r in 0..rows {
            for i in 0..c {
                for j in 0..c {
                    hess[(i, j)] += x[r * c + i] * x[r * c + j] / rows as f64;
                }
            }
        }
        let loss = |q: &gsr::quant::QuantizedLinear| -> f64 {
            let dw = {
                let deq = q.dequant();
                Mat::from_fn(c, h, |r, cc| deq[(r, cc)] - w[(r, cc)])
            };
            let hdw = hess.matmul(&dw);
            dw.data.iter().zip(&hdw.data).map(|(a, b)| a * b).sum()
        };
        let lg = loss(&gptq_quantize(&w, &hess, 2, group, true));
        let lr = loss(&rtn_quantize(&w, 2, group, true));
        assert!(lg <= lr * 1.02 + 1e-9, "seed {seed}: gptq {lg} vs rtn {lr}");
    });
}

#[test]
fn prop_hessian_partial_merge_is_order_invariant() {
    // Streaming calibration merges per-thread partials; any merge order
    // must agree up to fp associativity (addition is commutative, so
    // reordering only reshuffles rounding). Checked against a shuffled
    // merge order with a tight relative tolerance.
    for_seeds(16, |seed, rng| {
        let dim = 4 * (1 + rng.next_below(6) as usize);
        let n_parts = 3 + rng.next_below(4) as usize;
        let parts: Vec<HessianAccum> = (0..n_parts)
            .map(|_| {
                let mut acc = HessianAccum::new(dim);
                for _ in 0..(2 + rng.next_below(6)) {
                    let row: Vec<f32> =
                        (0..dim).map(|_| (rng.next_normal() * 2.0) as f32).collect();
                    acc.add_row(&row);
                }
                acc
            })
            .collect();
        let mut forward = HessianAccum::new(dim);
        for p in &parts {
            forward.merge(p);
        }
        // Fisher–Yates order shuffle.
        let mut order: Vec<usize> = (0..n_parts).collect();
        for i in (1..n_parts).rev() {
            order.swap(i, rng.next_below(i as u64 + 1) as usize);
        }
        let mut shuffled = HessianAccum::new(dim);
        for &i in &order {
            shuffled.merge(&parts[i]);
        }
        for (a, b) in forward.data.iter().zip(&shuffled.data) {
            let scale = a.abs().max(b.abs()).max(1.0);
            assert!(
                (a - b).abs() <= 1e-9 * scale,
                "seed {seed}: merge order changed a Hessian entry ({a} vs {b})"
            );
        }
    });
}

#[test]
fn prop_calibrated_gptq_no_worse_than_identity_on_calib_inputs() {
    // The calibrated-pipeline contract: on the calibration inputs
    // themselves (loss tr(ΔWᵀ H ΔW) with H = XᵀX streamed through the
    // calib accumulator), GPTQ fed the real Hessian must not lose to
    // GPTQ fed the identity.
    for_seeds(8, |seed, rng| {
        let c = 32;
        let h = 8;
        let group = 8;
        let w = Mat::from_fn(c, h, |_, _| rng.next_normal());
        let mut acc = HessianAccum::new(c);
        let rows = 96;
        for _ in 0..rows {
            let base = rng.next_normal();
            let row: Vec<f32> = (0..c)
                .map(|j| {
                    let amp = if j % 9 == 0 { 5.0 } else { 1.0 };
                    (amp * (0.5 * base + 0.5 * rng.next_normal())) as f32
                })
                .collect();
            acc.add_row(&row);
        }
        let hess = acc.to_mat(rows);
        let loss = |q: &gsr::quant::QuantizedLinear| -> f64 {
            let deq = q.dequant();
            let dw = Mat::from_fn(c, h, |r, cc| deq[(r, cc)] - w[(r, cc)]);
            let hdw = hess.matmul(&dw);
            dw.data.iter().zip(&hdw.data).map(|(a, b)| a * b).sum()
        };
        let cal = loss(&gptq_quantize(&w, &hess, 2, group, true));
        let ident = loss(&gptq_quantize(&w, &Mat::identity(c), 2, group, true));
        assert!(
            cal <= ident * 1.02 + 1e-9,
            "seed {seed}: calibrated {cal} vs identity {ident}"
        );
    });
}

#[test]
fn prop_rht_deterministic_and_orthonormal() {
    for_seeds(12, |seed, rng| {
        let n = rand_pow2(rng, 2, 8);
        let s = rng.next_u64();
        let a = rht(n, &mut SplitMix64::new(s));
        let b = rht(n, &mut SplitMix64::new(s));
        assert_eq!(a, b, "seed {seed}");
        assert!(a.orthogonality_defect() < 1e-9, "seed {seed}");
    });
}

#[test]
fn prop_batcher_preserves_request_multiset() {
    use gsr::coordinator::{BatchPolicy, DynamicBatcher};
    use std::time::Duration;
    for_seeds(16, |seed, rng| {
        let max_batch = 1 + rng.next_below(7) as usize;
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch,
            max_wait: Duration::from_secs(1),
        });
        let mut pushed = 0u64;
        let mut taken = Vec::new();
        for _ in 0..300 {
            if rng.next_below(3) < 2 {
                b.push(pushed);
                pushed += 1;
            } else if !b.is_empty() {
                let batch = b.take_batch();
                assert!(batch.len() <= max_batch, "seed {seed}: over-full batch");
                taken.extend(batch);
            }
        }
        while !b.is_empty() {
            taken.extend(b.take_batch());
        }
        let expect: Vec<u64> = (0..pushed).collect();
        assert_eq!(taken, expect, "seed {seed}: FIFO loss/dup/reorder");
    });
}

#[test]
fn prop_router_in_flight_balanced() {
    use gsr::coordinator::{RoutePolicy, Router};
    for_seeds(12, |seed, rng| {
        let mut r = Router::new(RoutePolicy::LeastLoaded);
        let n = 2 + rng.next_below(4) as usize;
        for i in 0..n {
            r.register(&format!("v{i}"));
        }
        let mut outstanding: Vec<String> = Vec::new();
        for _ in 0..200 {
            if rng.next_below(2) == 0 {
                outstanding.push(r.route(None).unwrap());
            } else if !outstanding.is_empty() {
                let idx = rng.next_below(outstanding.len() as u64) as usize;
                let v = outstanding.swap_remove(idx);
                r.complete(&v);
            }
            // Invariant: accounting matches outstanding exactly.
            assert_eq!(r.total_in_flight(), outstanding.len(), "seed {seed}");
            // Least-loaded keeps the spread tight (≤ 1 after each route).
        }
    });
}

/// Decode-path parity: for every rotation-plan kind — identity (the
/// unrotated fp checkpoint), a uniform global Walsh plan, and a
/// heterogeneous searched-style plan with a per-layer basis change and
/// R4 override — a KV-cached prefill + per-token decode yields logits
/// **bit-identical** to a full `forward` of the prefix at every step,
/// both at the library level and through the `NativeBackend` generation
/// contract at several thread counts (intra-sequence sharding active).
#[test]
fn prop_cached_decode_bit_identical_to_full_forward() {
    use gsr::exec::{Backend, NativeBackend};
    use gsr::model::{
        DenseModel, ForwardScratch, FpParams, KernelMode, KvCache, ModelCfg, R4Kind,
    };
    use gsr::quant::{build_plan_rotations, quantize_native_plan, RotationPlan, RotationSpec};
    use std::sync::Arc;

    let cfg = ModelCfg {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    };
    let assert_bits = |got: &[f32], want: &[f32], what: &str| {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (a, b)) in got.iter().zip(want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{what}: logit {i} ({a} vs {b})");
        }
    };
    for_seeds(4, |seed, rng| {
        let fp = FpParams::synthetic(&cfg, 100 + seed);
        let mut models: Vec<(&str, Arc<DenseModel>)> = vec![(
            "identity",
            Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() }),
        )];
        let gw_plan = RotationPlan::uniform(
            RotationSpec {
                r1: R1Kind::GW,
                r1_block: cfg.d_model,
                r4: R4Kind::GH,
                r4_block: cfg.d_ffn,
                r1_angles: 0,
            },
            cfg.n_layers,
            7 + seed,
        );
        let het_plan = RotationPlan {
            seed: 11 + seed,
            layers: vec![
                RotationSpec {
                    r1: R1Kind::GSR,
                    r1_block: 8,
                    r4: R4Kind::GH,
                    r4_block: cfg.d_ffn,
                    r1_angles: 0,
                },
                RotationSpec {
                    r1: R1Kind::GH,
                    r1_block: cfg.d_model,
                    r4: R4Kind::LH,
                    r4_block: 16,
                    r1_angles: 0,
                },
            ],
        };
        // Each quantized plan runs in both kernel modes: the decode
        // parity property (cached step ≡ full re-forward, at any thread
        // count) must hold for the packed fast kernels exactly as it
        // does for the f64 reference — each mode against itself.
        for (label, fast_label, plan) in [
            ("global-walsh", "global-walsh-fast", gw_plan),
            ("hetero", "hetero-fast", het_plan),
        ] {
            let rots = build_plan_rotations(&cfg, &plan).unwrap();
            let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
            let mut qp_fast = qp.clone();
            qp_fast.kernels = KernelMode::Fast;
            models.push((
                label,
                Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None }),
            ));
            models.push((
                fast_label,
                Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp_fast, a_bits: None }),
            ));
        }
        let prompt_len = 1 + rng.next_below(6) as usize;
        let decode_len = 1 + rng.next_below(6) as usize;
        let total = prompt_len + decode_len;
        let seq: Vec<i32> =
            (0..total).map(|_| rng.next_below(cfg.vocab as u64) as i32).collect();
        let v = cfg.vocab;
        for (label, model) in &models {
            // Library level: prefill + decode against the full forward.
            let mut cache = KvCache::new(&cfg, total);
            let mut scratch = ForwardScratch::new();
            let prefill =
                model.forward_cached(&seq[..prompt_len], &mut cache, &mut scratch).unwrap();
            let full = model.forward(&seq[..prompt_len]);
            assert_bits(&prefill, &full, &format!("seed {seed} {label} prefill"));
            for step in prompt_len..total {
                let got =
                    model.forward_cached(&seq[step..step + 1], &mut cache, &mut scratch).unwrap();
                let full = model.forward(&seq[..step + 1]);
                assert_bits(
                    &got,
                    &full[step * v..],
                    &format!("seed {seed} {label} decode step {step}"),
                );
            }
            // Backend level: the generation contract, serial and with
            // intra-sequence sharding across pool workers.
            for threads in [1usize, 3] {
                let backend = NativeBackend::new(Arc::clone(model), 2, total, threads);
                let (mut gen, last) = backend.start_generation(&seq[..prompt_len]).unwrap();
                let full = model.forward(&seq[..prompt_len]);
                assert_bits(
                    &last,
                    &full[(prompt_len - 1) * v..],
                    &format!("seed {seed} {label} t={threads} prefill tail"),
                );
                for step in prompt_len..total {
                    let got = backend.decode(&mut gen, seq[step]).unwrap();
                    let full = model.forward(&seq[..step + 1]);
                    assert_bits(
                        &got,
                        &full[step * v..],
                        &format!("seed {seed} {label} t={threads} decode step {step}"),
                    );
                }
                assert_eq!(gen.len(), total, "seed {seed} {label}: cache occupancy");
            }
        }
    });
}

/// Every candidate rotation family — the seeded kinds at random build
/// seeds AND the parametric GIV/BFLY kinds at **random angle words** —
/// produces an orthogonal matrix within tolerance, at every valid
/// (n, block) geometry the sweep draws. Orthogonality is what makes a
/// rotation "free": it is the invariant that lets a plan swap kinds
/// per layer without touching model function.
#[test]
fn prop_all_candidate_kinds_orthonormal_including_random_angles() {
    use gsr::transform::{try_build_parametric, try_build_r1};

    for_seeds(24, |seed, rng| {
        let n = rand_pow2(rng, 3, 8);
        let block = rand_pow2(rng, 1, 6).min(n);
        for kind in R1Kind::EXTENDED {
            let m = if kind.is_parametric() {
                let angles = rng.next_u64();
                try_build_parametric(kind, n, block, angles)
                    .unwrap_or_else(|e| panic!("seed {seed} kind {kind} n {n} block {block}: {e}"))
            } else {
                let b = if kind.is_local() { block } else { n };
                try_build_r1(kind, n, b, rng)
                    .unwrap_or_else(|e| panic!("seed {seed} kind {kind} n {n} block {block}: {e}"))
            };
            let defect = m.orthogonality_defect();
            assert!(defect < 1e-9, "seed {seed} kind {kind} n {n} block {block} defect {defect}");
        }
    });
}

/// A searched-style heterogeneous plan whose layers use the parametric
/// GIV/BFLY kinds (at non-default angle words) quantizes to a model
/// whose forward is **bit-exactly** invariant under (a) a plan-JSON
/// round-trip — the reloaded plan rebuilds the identical rotations from
/// the spec alone — and (b) the executor thread count (1 vs 3).
#[test]
fn prop_parametric_plan_forward_invariant_under_roundtrip_and_threads() {
    use gsr::config::Json;
    use gsr::exec::{Backend, NativeBackend};
    use gsr::model::{DenseModel, FpParams, ModelCfg, R4Kind};
    use gsr::quant::{build_plan_rotations, quantize_native_plan, RotationPlan, RotationSpec};
    use std::sync::Arc;

    let cfg = ModelCfg {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    };
    for_seeds(3, |seed, rng| {
        let fp = FpParams::synthetic(&cfg, 300 + seed);
        let plan = RotationPlan {
            seed: 17 + seed,
            layers: vec![
                RotationSpec {
                    r1: R1Kind::GIV,
                    r1_block: 16,
                    r4: R4Kind::GH,
                    r4_block: cfg.d_ffn,
                    r1_angles: rng.next_u64(),
                }
                .canonical(&cfg),
                RotationSpec {
                    r1: R1Kind::BFLY,
                    r1_block: 8,
                    r4: R4Kind::LH,
                    r4_block: 8,
                    r1_angles: rng.next_u64(),
                }
                .canonical(&cfg),
            ],
        };
        let text = plan.to_json().to_string_pretty();
        let reloaded = RotationPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(reloaded, plan, "seed {seed}: JSON round-trip must be lossless");
        assert_eq!(reloaded.fingerprint(), plan.fingerprint(), "seed {seed}");

        let tokens: Vec<i32> =
            (0..12).map(|_| rng.next_below(cfg.vocab as u64) as i32).collect();
        let mut logits = Vec::new();
        for p in [&plan, &reloaded] {
            let rots = build_plan_rotations(&cfg, p).unwrap();
            let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
            let model =
                Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None });
            for threads in [1usize, 3] {
                let backend = NativeBackend::new(Arc::clone(&model), 1, tokens.len(), threads);
                logits.push(backend.forward_batch(&tokens).unwrap());
            }
        }
        let want = &logits[0];
        for (i, got) in logits.iter().enumerate().skip(1) {
            assert_eq!(got.len(), want.len(), "seed {seed} variant {i}");
            for (j, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "seed {seed} variant {i} logit {j}: forward must be bit-invariant \
                     under plan round-trip and thread count"
                );
            }
        }
    });
}
