//! Paged-KV property and boundary tests: the block allocator's
//! conservation/determinism invariants, `KvCache` paged-mode behavior at
//! block edges (grant, reclaim, clear, failed-chunk rollback), and the
//! backend's paged generation contract surviving preemption (reclaim +
//! recompute-on-resume) bit-identically.
//!
//! proptest is not available in this offline image, so this file uses
//! the repo's minimal harness idiom: deterministic SplitMix64-driven
//! case generation; a failing seed reproduces exactly.

use std::sync::Arc;

use gsr::exec::{greedy_argmax, Backend, Generation, NativeBackend};
use gsr::model::{DenseModel, ForwardScratch, FpParams, KvBlock, KvCache, ModelCfg};
use gsr::rng::SplitMix64;
use gsr::sched::{blocks_for, BlockPool};

/// Run `prop` for `cases` deterministic seeds; panic names the seed.
fn for_seeds(cases: u64, prop: impl Fn(u64, &mut SplitMix64)) {
    for seed in 0..cases {
        let mut rng = SplitMix64::new(0xB10C ^ (seed * 0x9E37_79B9));
        prop(seed, &mut rng);
    }
}

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

fn assert_bits(got: &[f32], want: &[f32], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (a, b)) in got.iter().zip(want).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{what}: logit {i} differs ({a} vs {b})");
    }
}

/// No double-allocation, free-list conservation: under a random
/// alloc/release stream, every outstanding block id is unique and
/// `free + held == total` at every step.
#[test]
fn prop_pool_never_double_allocates_and_conserves_blocks() {
    for_seeds(16, |seed, rng| {
        let total = 1 + rng.next_below(12) as usize;
        let page = 1 + rng.next_below(6) as usize;
        let mut pool = BlockPool::new(2, 4, page, total);
        assert_eq!(pool.total_tokens(), total * page);
        let mut held: Vec<KvBlock> = Vec::new();
        for step in 0..200 {
            if rng.next_below(2) == 0 {
                if let Some(b) = pool.alloc() {
                    assert!(
                        held.iter().all(|h| h.id() != b.id()),
                        "seed {seed} step {step}: id {} granted twice",
                        b.id()
                    );
                    held.push(b);
                }
            } else if !held.is_empty() {
                let i = rng.next_below(held.len() as u64) as usize;
                pool.release(held.swap_remove(i));
            }
            assert_eq!(
                pool.free_blocks() + held.len(),
                total,
                "seed {seed} step {step}: blocks leaked or forged"
            );
            assert_eq!(pool.in_use(), held.len(), "seed {seed} step {step}: in_use drifted");
        }
    });
}

/// Deterministic allocation order: `alloc` is a pure function of the
/// free set — it always returns the lowest free id — so identical
/// alloc/release streams always receive identical block-id sequences.
#[test]
fn prop_pool_allocates_lowest_free_id() {
    for_seeds(16, |seed, rng| {
        let total = 2 + rng.next_below(10) as usize;
        let mut pool = BlockPool::new(1, 2, 2, total);
        let mut held: Vec<KvBlock> = Vec::new();
        let mut free_model: Vec<u32> = (0..total as u32).collect();
        for step in 0..200 {
            if rng.next_below(2) == 0 {
                let want = free_model.iter().copied().min();
                let got = pool.alloc().map(|b| {
                    let id = b.id();
                    held.push(b);
                    id
                });
                assert_eq!(got, want, "seed {seed} step {step}: not lowest-free-id");
                if let Some(id) = got {
                    free_model.retain(|&f| f != id);
                }
            } else if !held.is_empty() {
                let i = rng.next_below(held.len() as u64) as usize;
                let b = held.swap_remove(i);
                free_model.push(b.id());
                pool.release(b);
            }
        }
    });
}

/// Grant/reclaim boundary behavior through the public `KvCache` API:
/// geometry mismatches are refused without changing capacity, reclaim
/// empties the table, and contiguous caches opt out of both.
#[test]
fn paged_cache_grant_reclaim_and_geometry_checks() {
    let cfg = tiny_cfg();
    let mut cache = KvCache::paged(&cfg, 4);
    assert!(cache.is_paged());
    assert_eq!(cache.page_size(), Some(4));
    assert_eq!((cache.len(), cache.capacity()), (0, 0));
    assert!(cache.grant(KvBlock::new(9, 1, 4, 32)).is_err(), "layer mismatch");
    assert!(cache.grant(KvBlock::new(9, 2, 3, 32)).is_err(), "page mismatch");
    assert!(cache.grant(KvBlock::new(9, 2, 4, 16)).is_err(), "width mismatch");
    assert_eq!(cache.capacity(), 0, "failed grants must not change capacity");
    cache.grant(KvBlock::new(0, 2, 4, 32)).unwrap();
    cache.grant(KvBlock::new(1, 2, 4, 32)).unwrap();
    assert_eq!((cache.capacity(), cache.block_ids()), (8, vec![0, 1]));
    let blocks = cache.reclaim_blocks();
    assert_eq!(blocks.iter().map(|b| b.id()).collect::<Vec<_>>(), vec![0, 1]);
    assert_eq!((cache.len(), cache.capacity()), (0, 0));
    let mut contig = KvCache::new(&cfg, 8);
    assert!(!contig.is_paged());
    assert_eq!(contig.page_size(), None);
    assert!(contig.grant(KvBlock::new(0, 2, 4, 32)).is_err());
    assert!(contig.reclaim_blocks().is_empty());
    assert_eq!(contig.capacity(), 8, "a contiguous cache keeps its capacity");
}

/// Block-edge parity and rollback for every page size: chunked paged
/// forwards are bit-identical to the full forward however chunks
/// straddle block edges; zero-capacity and full caches refuse cleanly
/// with the cache rolled back; `clear` keeps the granted blocks.
#[test]
fn paged_forward_parity_and_rollback_at_block_edges() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 29);
    let model = DenseModel::Fp { cfg: cfg.clone(), params: fp };
    let v = cfg.vocab;
    let tokens: Vec<i32> = (0..13).map(|i| ((i * 7 + 3) % v) as i32).collect();
    let full = model.forward(&tokens);
    let last = &full[(tokens.len() - 1) * v..];
    for page in [1usize, 3, 4, 16] {
        let mut cache = KvCache::paged(&cfg, page);
        let mut scratch = ForwardScratch::new();
        // Zero granted capacity refuses and stays empty.
        let err = model.forward_cached(&tokens[..2], &mut cache, &mut scratch);
        assert!(err.is_err(), "page {page}: chunk beyond capacity must fail");
        assert_eq!(cache.len(), 0, "page {page}: failed chunk must roll back");
        let n_blocks = blocks_for(tokens.len(), page);
        for id in 0..n_blocks {
            cache.grant(KvBlock::new(id as u32, cfg.n_layers, page, cfg.d_model)).unwrap();
        }
        // Uneven chunks straddle the block edges on small pages.
        let mut got = Vec::new();
        for chunk in tokens.chunks(page.max(2) - 1) {
            got = model.forward_cached(chunk, &mut cache, &mut scratch).unwrap();
        }
        assert_eq!(cache.len(), tokens.len());
        let got_last = &got[(got.len() / v - 1) * v..];
        assert_bits(got_last, last, &format!("page {page} chunked"));
        // A full cache refuses the next token and stays intact.
        if cache.remaining() == 0 {
            let e = model.forward_cached(&[1], &mut cache, &mut scratch);
            assert!(e.is_err(), "page {page}: full cache must refuse");
            assert_eq!(cache.len(), tokens.len(), "page {page}: refusal must not corrupt");
        }
        // clear() keeps granted blocks; a rerun lands on the same bits.
        cache.clear();
        assert_eq!((cache.len(), cache.capacity()), (0, n_blocks * page));
        let again = model.forward_cached(&tokens, &mut cache, &mut scratch).unwrap();
        let again_last = &again[(again.len() / v - 1) * v..];
        assert_bits(again_last, last, &format!("page {page} clear+rerun"));
    }
}

/// A cache granted exactly one block fills to the block edge, refuses
/// the token past it, and resumes bit-identically once the next block
/// is granted — the grant boundary is invisible to the logits.
#[test]
fn decode_resumes_across_a_block_edge() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 37);
    let model = DenseModel::Fp { cfg: cfg.clone(), params: fp };
    let v = cfg.vocab;
    let page = 4;
    let tokens: Vec<i32> = (0..=page).map(|i| ((i * 5 + 2) % v) as i32).collect();
    let full = model.forward(&tokens);
    let mut cache = KvCache::paged(&cfg, page);
    let mut scratch = ForwardScratch::new();
    cache.grant(KvBlock::new(0, cfg.n_layers, page, cfg.d_model)).unwrap();
    model.forward_cached(&tokens[..page], &mut cache, &mut scratch).unwrap();
    assert_eq!(cache.remaining(), 0, "block edge reached");
    let e = model.forward_cached(&tokens[page..], &mut cache, &mut scratch);
    assert!(e.is_err(), "full cache must refuse the next token");
    assert_eq!(cache.len(), page, "refusal must leave the cache intact");
    cache.grant(KvBlock::new(1, cfg.n_layers, page, cfg.d_model)).unwrap();
    let got = model.forward_cached(&tokens[page..], &mut cache, &mut scratch).unwrap();
    assert_bits(&got, &full[page * v..], "across the block edge");
}

/// Grow a paged generation's capacity until `tokens` fits, absorbing in
/// 2-token chunks — the driver loop the scheduler runs, reduced to its
/// essence for the contract test below.
fn feed_chunks(
    backend: &NativeBackend,
    pool: &mut BlockPool,
    gen: &mut Generation,
    tokens: &[i32],
) -> Vec<f32> {
    let mut out = Vec::new();
    for chunk in tokens.chunks(2) {
        while gen.remaining() < chunk.len() {
            backend.grant_kv_block(gen, pool.alloc().expect("pool dry")).unwrap();
        }
        out = backend.prefill_chunk(gen, chunk).unwrap();
    }
    out
}

/// The speculative verify/rollback contract at block edges: a
/// `verify_draft` batch whose absorbed positions straddle a block
/// boundary returns one bit-exact logit row per position (equal to the
/// plain decode chain wherever the fed tokens agree), `rollback_generation`
/// to the last accepted position frees exactly the tail blocks past it
/// (returned to the pool, conservation intact), and the resumed decode
/// is bit-identical to a generation that never drafted.
#[test]
fn prop_verify_rollback_across_block_edges_is_bit_exact() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 53);
    let model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp });
    let backend = NativeBackend::new(Arc::clone(&model), 2, 24, 2);
    let (nl, w) = backend.kv_block_geometry().expect("native backend is paged-capable");
    let v = cfg.vocab;
    let page = 4;
    let mut rolled_past_an_edge = 0usize;
    for_seeds(8, |seed, rng| {
        let plen = 5 + rng.next_below(4) as usize; // prompt 5..=8
        let k = 2 + rng.next_below(3) as usize; // drafts 2..=4 per round
        let accepted = rng.next_below(k as u64 + 1) as usize; // 0..=k
        let mut pool = BlockPool::new(nl, w, page, blocks_for(24, page));
        let prompt: Vec<i32> = (0..plen).map(|i| ((i * 11 + seed as usize) % v) as i32).collect();

        // Reference chain: plain decode, never drafting. `toks[0]` is
        // the pick off the prefill logits (the pending token);
        // `ref_logits[j]` for j >= 1 is the row after absorbing
        // `toks[j - 1]`.
        let mut ref_gen = backend.start_paged_generation(page).unwrap();
        let last = feed_chunks(&backend, &mut pool, &mut ref_gen, &prompt);
        let mut toks = vec![greedy_argmax(&last)];
        let mut ref_logits = vec![last];
        for _ in 0..k + 2 {
            if ref_gen.remaining() < 1 {
                backend.grant_kv_block(&mut ref_gen, pool.alloc().unwrap()).unwrap();
            }
            let l = backend.decode(&mut ref_gen, *toks.last().unwrap()).unwrap();
            toks.push(greedy_argmax(&l));
            ref_logits.push(l);
        }
        for b in backend.reclaim_kv_blocks(&mut ref_gen).unwrap() {
            pool.release(b);
        }
        assert_eq!(pool.in_use(), 0, "seed {seed}: reference blocks leaked");

        // Speculative path: same prompt, then one verify batch feeding
        // the pending token plus k drafts — the first `accepted` of
        // them correct, the rest deliberately wrong.
        let mut gen = backend.start_paged_generation(page).unwrap();
        feed_chunks(&backend, &mut pool, &mut gen, &prompt);
        let base = gen.len();
        assert_eq!(base, plen);
        let mut verify = vec![toks[0]];
        for j in 0..k {
            let t = toks[j + 1];
            verify.push(if j < accepted { t } else { (t + 1) % v as i32 });
        }
        while gen.remaining() < verify.len() {
            backend.grant_kv_block(&mut gen, pool.alloc().unwrap()).unwrap();
        }
        let rows = backend.verify_draft(&mut gen, &verify).unwrap();
        assert_eq!(rows.len(), verify.len() * v, "seed {seed}: one row per absorbed position");
        assert_eq!(gen.len(), base + k + 1, "seed {seed}: verify absorbs every fed token");
        // Rows where the fed prefix matches the reference chain must be
        // bit-identical to the plain decode logits.
        for j in 0..=accepted.min(k) {
            assert_bits(
                &rows[j * v..(j + 1) * v],
                &ref_logits[j + 1],
                &format!("seed {seed}: verify row {j}"),
            );
        }

        // Roll back to the last kept position: pending pick + accepted
        // drafts. Exactly the tail blocks past it come back.
        let keep = base + 1 + accepted;
        let past_end = gen.len() + 1;
        assert!(
            backend.rollback_generation(&mut gen, past_end).is_err(),
            "seed {seed}: rollback beyond occupancy must refuse"
        );
        let freed = backend.rollback_generation(&mut gen, keep).unwrap();
        let want_freed = blocks_for(base + k + 1, page) - blocks_for(keep, page);
        assert_eq!(freed.len(), want_freed, "seed {seed}: tail blocks past keep are freed");
        rolled_past_an_edge += usize::from(want_freed > 0);
        assert_eq!(gen.len(), keep, "seed {seed}: rollback lands on keep");
        assert_eq!(
            gen.capacity(),
            blocks_for(keep, page) * page,
            "seed {seed}: capacity shrinks with the freed blocks"
        );
        for b in freed {
            pool.release(b);
        }
        assert_eq!(
            pool.in_use() * page,
            gen.capacity(),
            "seed {seed}: pool inventory conserved through rollback"
        );

        // Resume decoding from the correction pick: bit-identical to
        // the chain that never drafted.
        if gen.remaining() < 1 {
            backend.grant_kv_block(&mut gen, pool.alloc().unwrap()).unwrap();
        }
        let l = backend.decode(&mut gen, toks[accepted + 1]).unwrap();
        assert_bits(
            &l,
            &ref_logits[accepted + 2],
            &format!("seed {seed}: post-rollback decode"),
        );
        assert_eq!(gen.len(), keep + 1);
    });
    assert!(
        rolled_past_an_edge >= 1,
        "the seed sweep must include a rollback that crosses a block edge"
    );
}

/// The backend's paged contract end to end: chunked prefill matches the
/// contiguous prefill bit-for-bit, reclaim returns every block to the
/// pool (conservation), and a preempted sequence that recomputes its
/// prefix resumes on exactly the same logits.
#[test]
fn backend_paged_generation_survives_reclaim_and_resume() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 43);
    let model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp });
    let backend = NativeBackend::new(Arc::clone(&model), 2, 16, 2);
    let (nl, w) = backend.kv_block_geometry().expect("native backend is paged-capable");
    let page = 3;
    let mut pool = BlockPool::new(nl, w, page, blocks_for(16, page));
    let prompt: Vec<i32> = (0..5).map(|i| ((i * 11 + 1) % cfg.vocab) as i32).collect();
    // Reference: contiguous generation, greedy picks.
    let (mut cgen, first) = backend.start_generation(&prompt).unwrap();
    let mut want = vec![first];
    for _ in 0..3 {
        let tok = greedy_argmax(want.last().unwrap());
        let l = backend.decode(&mut cgen, tok).unwrap();
        want.push(l);
    }
    // Paged: chunked prefill, one decode, then preemption and resume.
    let mut gen = backend.start_paged_generation(page).unwrap();
    let got0 = feed_chunks(&backend, &mut pool, &mut gen, &prompt);
    assert_bits(&got0, &want[0], "chunked prefill logits");
    let pick0 = greedy_argmax(&got0);
    if gen.remaining() < 1 {
        backend.grant_kv_block(&mut gen, pool.alloc().unwrap()).unwrap();
    }
    let got1 = backend.decode(&mut gen, pick0).unwrap();
    assert_bits(&got1, &want[1], "paged decode step");
    let pick1 = greedy_argmax(&got1);
    // Preempt: every block moves back to the pool, the generation
    // drops to zero occupancy.
    let blocks = backend.reclaim_kv_blocks(&mut gen).unwrap();
    assert!(!blocks.is_empty(), "an active sequence holds blocks");
    assert_eq!((gen.len(), gen.capacity()), (0, 0));
    for b in blocks {
        pool.release(b);
    }
    assert_eq!(pool.in_use(), 0, "reclaim + release must conserve the inventory");
    // Resume: recompute prompt + produced tokens, then keep decoding —
    // bit-identical to the uninterrupted contiguous run.
    let mut stream = prompt.clone();
    stream.extend([pick0, pick1]);
    let got2 = feed_chunks(&backend, &mut pool, &mut gen, &stream);
    assert_bits(&got2, &want[2], "recomputed resume logits");
    let pick2 = greedy_argmax(&got2);
    if gen.remaining() < 1 {
        backend.grant_kv_block(&mut gen, pool.alloc().unwrap()).unwrap();
    }
    let got3 = backend.decode(&mut gen, pick2).unwrap();
    assert_bits(&got3, &want[3], "post-resume decode");
}
