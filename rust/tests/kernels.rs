//! Kernel conformance suite for the packed-domain fast path
//! (`--kernels fast`). Pins, in order:
//!
//! * the fused packed dequant-matmul against the dense f64-accumulation
//!   reference over random shapes, group sizes and both bit widths;
//! * the FWHT structured-rotation application against dense Walsh
//!   matmuls, including a bit-exact check at power-of-4 sizes where
//!   every value is exactly representable;
//! * model-level conformance: fast logits within [`FAST_LOGIT_TOL`] of
//!   the reference forward for global-Hadamard, global-Walsh and
//!   heterogeneous GSR plans at 2 and 4 bits — and the fast logits
//!   themselves bit-stable across batch composition and thread count;
//! * the reference mode staying bit-identical with all the fast-path
//!   data (packed linears, rotation descriptors) attached;
//! * the `pack4` byte layout against the Python reference vectors
//!   (`python/compile/kernels/ref.py`).

use std::sync::Arc;

use gsr::exec::{Backend, NativeBackend};
use gsr::model::forward::matmul;
use gsr::model::{
    packed_matmul_into, DenseModel, FpParams, KernelMode, ModelCfg, PackedLinear, R1Desc, R4Kind,
    FAST_LOGIT_TOL,
};
use gsr::quant::{
    build_plan_rotations, pack4, quantize_native_plan, unpack4, RotationPlan, RotationSpec,
};
use gsr::rng::SplitMix64;
use gsr::transform::{walsh, R1Kind};

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

fn window(seed: usize, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 7 + seed * 13 + 1) % vocab) as i32).collect()
}

/// The three plan shapes the fast path must serve: a uniform global
/// Hadamard (sign path), a uniform global Walsh (sequency-permutation
/// path), and a heterogeneous plan whose layer boundary needs the
/// structured basis change (GSR blocks into a global Hadamard).
fn plans(cfg: &ModelCfg) -> Vec<(&'static str, RotationPlan)> {
    let gh = RotationPlan::uniform(
        RotationSpec {
            r1: R1Kind::GH,
            r1_block: cfg.d_model,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0,
        },
        cfg.n_layers,
        5,
    );
    let gw = RotationPlan::uniform(
        RotationSpec {
            r1: R1Kind::GW,
            r1_block: cfg.d_model,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0,
        },
        cfg.n_layers,
        6,
    );
    let het = RotationPlan {
        seed: 7,
        layers: vec![
            RotationSpec {
                r1: R1Kind::GSR,
                r1_block: 8,
                r4: R4Kind::GH,
                r4_block: cfg.d_ffn,
                r1_angles: 0,
            },
            RotationSpec {
                r1: R1Kind::GH,
                r1_block: cfg.d_model,
                r4: R4Kind::LH,
                r4_block: 16,
                r1_angles: 0,
            },
        ],
    };
    vec![("global-hadamard", gh), ("global-walsh", gw), ("hetero-gsr", het)]
}

/// Fused packed matmul vs the dense f64-accumulation reference over
/// random shapes, groups and both bit widths. The bound here is the
/// single-matmul bound (one f32 tile reduction per k-tile); the looser
/// end-to-end [`FAST_LOGIT_TOL`] compounds it across layers.
#[test]
fn packed_matmul_random_shapes_match_reference() {
    for seed in 0..12u64 {
        let mut rng = SplitMix64::new(0xACC ^ seed.wrapping_mul(0x9E37_79B9));
        let t = 1 + rng.next_below(5) as usize;
        let group = 8usize << rng.next_below(3); // 8, 16, 32
        let c = group * (1 + rng.next_below(5) as usize);
        let h = 1 + rng.next_below(200) as usize;
        let bits = if rng.next_below(2) == 0 { 2u32 } else { 4 };
        let qmax = (1u64 << bits) - 1;
        let codes: Vec<i32> = (0..c * h).map(|_| rng.next_below(qmax + 1) as i32).collect();
        let ng = c / group;
        let scale: Vec<f32> = (0..ng * h).map(|_| 0.01 + rng.next_f64() as f32 * 0.05).collect();
        let zero: Vec<f32> = (0..ng * h).map(|_| rng.next_below(qmax + 1) as f32).collect();
        let w = PackedLinear::from_codes(&codes, c, h, group, scale, zero, bits)
            .expect("supported geometry");
        let x: Vec<f32> = (0..t * c).map(|_| rng.next_normal() as f32).collect();
        let want = matmul(&x, &w.dequant_dense(), t, c, h);
        let (mut out, mut acc) = (Vec::new(), Vec::new());
        packed_matmul_into(&x, &w, t, &mut out, &mut acc);
        assert_eq!(out.len(), want.len());
        for (a, b) in out.iter().zip(&want) {
            let tol = 1e-4 * b.abs().max(1.0);
            assert!(
                (a - b).abs() <= tol,
                "seed {seed} t={t} c={c} h={h} g={group} w{bits}: {a} vs {b}"
            );
        }
    }
}

/// FWHT application of a sequency-ordered Walsh rotation vs the dense
/// matmul: close on random inputs at any power-of-2 size, and — at
/// power-of-4 sizes, where `1/√n` is a power of two and one-hot inputs
/// stay exactly representable — bit-identical.
#[test]
fn fwht_walsh_parity_and_pow4_bit_exactness() {
    let mut tmp = Vec::new();
    for n in [8usize, 32, 128] {
        let w = walsh(n);
        let desc = R1Desc::from_mat(R1Kind::GW, n, &w).expect("walsh recognized");
        let mut rng = SplitMix64::new(0x11A5 + n as u64);
        let x: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
        let xd: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let want = w.apply_right(&xd);
        let mut got = x;
        desc.forward_row(&mut got, &mut tmp);
        for (j, (a, b)) in got.iter().zip(&want).enumerate() {
            assert!(
                (*a as f64 - b).abs() <= 1e-5 * b.abs().max(1.0),
                "n={n} col {j}: {a} vs {b}"
            );
        }
    }
    for n in [4usize, 16, 64] {
        let w = walsh(n);
        let desc = R1Desc::from_mat(R1Kind::GW, n, &w).expect("walsh recognized");
        for k in [0usize, 1, n / 2, n - 1] {
            let mut x = vec![0f32; n];
            x[k] = 1.0;
            let xd: Vec<f64> = x.iter().map(|&v| v as f64).collect();
            let want: Vec<f32> = w.apply_right(&xd).iter().map(|&v| v as f32).collect();
            desc.forward_row(&mut x, &mut tmp);
            for (j, (a, b)) in x.iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "n={n} e_{k} col {j}: {a} vs {b}");
            }
        }
    }
}

/// The end-to-end conformance sweep: for every plan shape and both bit
/// widths, the fast forward stays within the pinned bound of the
/// reference forward, every structured representation the plan implies
/// was actually recognized (so the test cannot silently degrade into
/// fast==reference-via-fallback), and the fast logits are bit-stable
/// across batch composition and thread count.
#[test]
fn fast_logits_within_pinned_bound_across_plans_bits_batches_threads() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 17);
    let s = 12usize;
    let seqs: Vec<Vec<i32>> = (0..4).map(|i| window(i, s, cfg.vocab)).collect();
    for (label, plan) in plans(&cfg) {
        let rots = build_plan_rotations(&cfg, &plan).unwrap();
        for bits in [2u32, 4] {
            let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, bits);
            // The fast representations must be present — a regression
            // that stops recognizing them would otherwise pass this
            // test by silently running the dense fallback everywhere.
            assert!(qp.r3_fast.is_some(), "{label} w{bits}: R3 not recognized");
            for (l, layer) in qp.layers.iter().enumerate() {
                assert_eq!(layer.packed.len(), 7, "{label} w{bits} layer {l}: packed linears");
            }
            if label == "hetero-gsr" {
                assert!(
                    qp.layers[1].basis_fast.is_some(),
                    "{label} w{bits}: basis change not recognized"
                );
            }
            let reference =
                Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp.clone(), a_bits: None });
            let mut qpf = qp;
            qpf.kernels = KernelMode::Fast;
            let fast = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qpf, a_bits: None });
            let fast_serial: Vec<Vec<f32>> = seqs.iter().map(|q| fast.forward(q)).collect();
            for (i, (got, seq)) in fast_serial.iter().zip(&seqs).enumerate() {
                let want = reference.forward(seq);
                for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                    let tol = FAST_LOGIT_TOL * b.abs().max(1.0);
                    assert!(
                        (a - b).abs() <= tol,
                        "{label} w{bits} seq {i} logit {j}: fast {a} vs reference {b}"
                    );
                }
            }
            for threads in [1usize, 3] {
                for batch in [1usize, 2] {
                    let backend = NativeBackend::new(Arc::clone(&fast), batch, s, threads);
                    assert_eq!(backend.name(), "native-quant-fast");
                    let v = backend.vocab();
                    for chunk in seqs.chunks(batch) {
                        let mut tokens = vec![0i32; batch * s];
                        for (i, w) in chunk.iter().enumerate() {
                            tokens[i * s..(i + 1) * s].copy_from_slice(w);
                        }
                        let out = backend.forward_batch(&tokens).unwrap();
                        for (i, w) in chunk.iter().enumerate() {
                            let idx = seqs.iter().position(|x| x == w).unwrap();
                            let row = &out[i * s * v..(i + 1) * s * v];
                            for (j, (a, b)) in row.iter().zip(&fast_serial[idx]).enumerate() {
                                assert_eq!(
                                    a.to_bits(),
                                    b.to_bits(),
                                    "{label} w{bits} b={batch} t={threads} logit {j}: \
                                     fast mode must be batch/thread-stable"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

/// Attaching the fast-path data (packed linears, R3 descriptor, basis
/// descriptors) must never perturb the reference path: a reference-mode
/// model with everything attached is bit-identical to one stripped back
/// to the pre-kernel-layer parameter set.
#[test]
fn reference_mode_bit_identical_with_fast_data_attached() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 23);
    for (label, plan) in plans(&cfg) {
        let rots = build_plan_rotations(&cfg, &plan).unwrap();
        let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
        let mut stripped = qp.clone();
        stripped.r3_fast = None;
        for layer in &mut stripped.layers {
            layer.packed.clear();
            layer.basis_fast = None;
        }
        assert_eq!(qp.kernels, KernelMode::Reference, "reference must be the default");
        let with = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
        let without = DenseModel::Quant { cfg: cfg.clone(), params: stripped, a_bits: None };
        let tokens = window(3, 16, cfg.vocab);
        let a = with.forward(&tokens);
        let b = without.forward(&tokens);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{label} logit {i}: reference path perturbed");
        }
    }
}

/// The `pack4` byte layout, cross-referenced against the vectors pinned
/// on the Python side (`python/compile/kernels/ref.py`): two codes per
/// byte, low nibble = even input channel, bytes row-major `[C/2, H]`.
#[test]
fn pack4_layout_matches_python_reference_vectors() {
    assert_eq!(pack4(&[0xA, 0x5], 2, 1), vec![0x5A]);
    assert_eq!(pack4(&[1, 2, 3, 4, 5, 6, 7, 8], 4, 2), vec![0x31, 0x42, 0x75, 0x86]);
    for seed in 0..8u64 {
        let mut rng = SplitMix64::new(0xF0 ^ seed.wrapping_mul(0x9E37_79B9));
        let c = 2 * (1 + rng.next_below(40) as usize);
        let h = 1 + rng.next_below(30) as usize;
        let codes: Vec<i32> = (0..c * h).map(|_| rng.next_below(16) as i32).collect();
        assert_eq!(unpack4(&pack4(&codes, c, h), c, h), codes, "seed {seed}");
    }
}

/// Parametric (GIV/BFLY) layers on the fast path: their rotations have
/// no FWHT structure, so the fast kernels must (a) still serve the
/// model within the pinned logit bound — via packed linears everywhere
/// and a dense basis change where a parametric factor appears — and
/// (b) account the fallback **exactly**: only the layer whose basis
/// change involves a parametric factor registers one, and a uniform
/// parametric plan (no transitions) registers zero.
#[test]
fn parametric_plans_conform_and_count_dense_fallbacks_exactly() {
    use gsr::transform::default_angles;

    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 29);
    let uniform_giv = RotationPlan::uniform(
        RotationSpec {
            r1: R1Kind::GIV,
            r1_block: 16,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0x0718_2940_5B6C_7D8E,
        },
        cfg.n_layers,
        9,
    );
    let hetero_bfly = RotationPlan {
        seed: 10,
        layers: vec![
            RotationSpec {
                r1: R1Kind::GSR,
                r1_block: 8,
                r4: R4Kind::GH,
                r4_block: cfg.d_ffn,
                r1_angles: 0,
            },
            RotationSpec {
                r1: R1Kind::BFLY,
                r1_block: 16,
                r4: R4Kind::GH,
                r4_block: cfg.d_ffn,
                r1_angles: default_angles(R1Kind::BFLY, 16),
            },
        ],
    };
    // (plan, expected dense fallbacks beyond R3, which layers fall back)
    let cases = [
        ("uniform-giv", uniform_giv, 0usize, [false, false]),
        ("hetero-bfly", hetero_bfly, 1usize, [false, true]),
    ];
    for (label, plan, extra_fallbacks, layer_falls_back) in cases {
        let rots = build_plan_rotations(&cfg, &plan).unwrap();
        let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
        // Every linear still packs — parametric kinds only affect the
        // basis-change structure, never the packed-domain linears.
        for (l, layer) in qp.layers.iter().enumerate() {
            assert_eq!(layer.packed.len(), 7, "{label} layer {l}: packed linears");
            assert_eq!(
                layer.basis_change.is_some() && layer.basis_fast.is_none(),
                layer_falls_back[l],
                "{label} layer {l}: wrong fallback site"
            );
        }
        assert!(qp.r3_fast.is_some(), "{label}: R3 must still be recognized");
        let stats = qp.fast_path_stats();
        assert_eq!(
            stats.dense_fallbacks, extra_fallbacks,
            "{label}: fallback counter must count exactly the parametric \
             basis changes (got {stats:?})"
        );
        // Conformance: fast logits within the pinned bound of reference.
        let reference =
            Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp.clone(), a_bits: None });
        let mut qpf = qp;
        qpf.kernels = KernelMode::Fast;
        let fast = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qpf, a_bits: None });
        for (i, seq) in (0..3).map(|s| window(s, 12, cfg.vocab)).enumerate() {
            let got = fast.forward(&seq);
            let want = reference.forward(&seq);
            for (j, (a, b)) in got.iter().zip(&want).enumerate() {
                let tol = FAST_LOGIT_TOL * b.abs().max(1.0);
                assert!(
                    (a - b).abs() <= tol,
                    "{label} seq {i} logit {j}: fast {a} vs reference {b}"
                );
            }
        }
    }
}

/// Structure recognition must refuse parametric rotations rather than
/// mis-classify them: `R1Desc::from_mat` returns `None` for GIV and
/// BFLY matrices at any angle setting, which is what routes them to the
/// counted dense fallback instead of a silently wrong FWHT path.
#[test]
fn r1desc_never_claims_parametric_structure() {
    use gsr::transform::{default_angles, try_build_parametric};

    for kind in [R1Kind::GIV, R1Kind::BFLY] {
        for angles in [0u64, default_angles(kind, 16), 0xDEAD_BEEF_0123_4567] {
            let m = try_build_parametric(kind, 32, 16, angles).unwrap();
            assert!(
                R1Desc::from_mat(kind, 16, &m).is_none(),
                "{kind} angles {angles:#x}: parametric matrix must not be \
                 claimed as structured"
            );
        }
    }
}
