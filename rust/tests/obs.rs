//! End-to-end observability tests: the flight recorder capturing a
//! mixed generate+score load with preemption and exporting a
//! well-formed Chrome trace, the Prometheus exposition of the serving
//! metric families, and per-layer quantization telemetry reproducing
//! the paper's sequency-vs-Hadamard claim on a synthetic checkpoint.

use std::sync::Arc;
use std::time::Duration;

use gsr::config::Json;
use gsr::coordinator::{BatchPolicy, Server};
use gsr::exec::{NativeBackend, NativeSet};
use gsr::model::{weights::FpLayer, DenseModel, FpParams, ModelCfg, R4Kind};
use gsr::obs::{Obs, RequestKind, TraceEvent};
use gsr::quant::{
    build_plan_rotations, quantize_native_plan_telemetry, LayerQuantTelemetry, RotationPlan,
    RotationSpec,
};
use gsr::rng::SplitMix64;
use gsr::sched::{SamplingParams, SchedConfig};
use gsr::transform::R1Kind;

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

fn window(seed: usize, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|i| ((i * 7 + seed * 13 + 1) % vocab) as i32).collect()
}

fn fp_model(cfg: &ModelCfg, seed: u64) -> Arc<DenseModel> {
    let fp = FpParams::synthetic(cfg, seed);
    Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp })
}

/// Mixed generate+score load on a deliberately starved block pool (the
/// `paged_serving_completes_beyond_contiguous_capacity` recipe) with
/// the flight recorder on: the event stream must be well-formed —
/// per-shard monotone timestamps, every admitted request's span closed,
/// prefill and decode activity per generation, and at least one
/// preemption paired with its resume — and the Chrome export must
/// round-trip through a JSON parser with balanced async spans.
#[test]
fn trace_captures_mixed_load_with_preemption_and_exports() {
    let cfg = tiny_cfg();
    let fp_m = fp_model(&cfg, 23);
    let s = 8;
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 4, s, 2));
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) };
    let sched = SchedConfig { page_size: 4, kv_blocks: 5, prefill_chunk: 3, speculate: None };
    let obs = Obs::new();
    obs.recorder.enable();
    let server = Server::start_native_obs(set, policy, sched, &obs).unwrap();
    // 3 sequences each peak at 4 + 8 − 1 = 11 cached tokens against a
    // 20-token pool: the aggregate peak of 33 forces preemption.
    let mut pending = Vec::new();
    for i in 0..3 {
        let (reply, rx) = std::sync::mpsc::channel();
        server
            .submit_generate(gsr::coordinator::GenerateRequest {
                variant: "fp".to_string(),
                prompt: window(70 + i, 4, cfg.vocab),
                max_new: 8,
                stop: None,
                sampling: SamplingParams::greedy(),
                stream: None,
                reply,
            })
            .unwrap();
        pending.push(rx);
    }
    // Scoring traffic interleaves with the generation rounds.
    server.score("fp", window(77, s, cfg.vocab)).unwrap();
    for (i, rx) in pending.into_iter().enumerate() {
        rx.recv().unwrap().result.unwrap_or_else(|e| panic!("seq {i}: {e}"));
    }
    let metrics = server.shutdown();
    assert!(metrics.preemptions >= 1, "a contended pool must preempt");
    assert_eq!(obs.recorder.dropped_total(), 0, "load must fit the default rings");

    // Per-shard timestamps are non-decreasing.
    let shards = obs.recorder.snapshot();
    for (label, _, records) in &shards {
        for w in records.windows(2) {
            assert!(w[0].ts_us <= w[1].ts_us, "shard {label}: timestamps regressed");
        }
    }
    let events: Vec<&TraceEvent> =
        shards.iter().flat_map(|(_, _, r)| r.iter().map(|rec| &rec.event)).collect();
    let mut admitted = Vec::new();
    let mut generate_ids = Vec::new();
    let mut closed = Vec::new();
    let mut preempted = Vec::new();
    let mut resumed = Vec::new();
    let (mut prefills, mut decodes, mut batches) = (0, 0, 0);
    for e in &events {
        match e {
            TraceEvent::RequestAdmitted { id, kind, .. } => {
                admitted.push(*id);
                if *kind == RequestKind::Generate {
                    generate_ids.push(*id);
                }
            }
            TraceEvent::RequestRejected { variant, reason } => {
                panic!("unexpected rejection of {variant}: {reason}")
            }
            TraceEvent::RequestCompleted { id, .. } => closed.push(*id),
            TraceEvent::RequestFailed { id, error } => panic!("request {id} failed: {error}"),
            TraceEvent::PrefillChunk { .. } => prefills += 1,
            TraceEvent::DecodeRound { .. } => decodes += 1,
            TraceEvent::BatchExec { .. } => batches += 1,
            TraceEvent::Preempted { id, blocks, .. } => {
                assert!(*blocks >= 1, "a preemption victim holds blocks");
                preempted.push(*id);
            }
            TraceEvent::Resumed { id } => resumed.push(*id),
            _ => {}
        }
    }
    assert_eq!(admitted.len(), 4, "3 generations + 1 score admitted");
    assert_eq!(generate_ids.len(), 3);
    for id in &admitted {
        assert!(closed.contains(id), "request {id} admitted but never closed");
    }
    for id in &generate_ids {
        let n = events
            .iter()
            .filter(|e| matches!(e, TraceEvent::PrefillChunk { id: p, .. } if p == id))
            .count();
        assert!(n >= 1, "generation {id} has no prefill chunks");
    }
    assert!(prefills >= 3 && decodes >= 1 && batches >= 1, "all stages must appear");
    // fp variants have no kernel-mode notion, so no selection event
    // (the quantized case is covered by the Prometheus test below).
    let kernel_paths =
        events.iter().filter(|e| matches!(e, TraceEvent::KernelPath { .. })).count();
    assert_eq!(kernel_paths, 0);
    assert!(!preempted.is_empty(), "metrics saw a preemption, the trace must too");
    for id in &preempted {
        assert!(resumed.contains(id), "preempted sequence {id} never resumed");
    }

    // Chrome export round-trips: parseable, balanced b/e spans, thread
    // metadata and complete slices present; `gsr trace` agrees.
    let dir = std::env::temp_dir().join("gsr_obs_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("trace.json");
    obs.recorder.write(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let root = Json::parse(&text).unwrap();
    let chrome = root.at("traceEvents").unwrap().as_arr().unwrap();
    let ph_count = |ph: &str| {
        chrome.iter().filter(|e| e.get("ph").and_then(|p| p.as_str()) == Some(ph)).count()
    };
    assert_eq!(ph_count("b"), 4, "one span open per admitted request");
    assert_eq!(ph_count("e"), 4, "every span closed");
    assert!(ph_count("M") >= 1, "thread metadata present");
    assert!(ph_count("X") >= 4, "prefill/decode/batch become complete slices");
    let summary = gsr::obs::trace::inspect(&path).unwrap();
    assert!(summary.contains("0 unclosed"), "{summary}");
    assert!(summary.contains("preempted"), "{summary}");
    std::fs::remove_file(&path).ok();
}

/// The recorder off (the default) leaves the event stream empty for
/// the same served load — instrumentation must not record or allocate
/// shards' worth of events when disabled.
#[test]
fn disabled_recorder_stays_empty_under_load() {
    let cfg = tiny_cfg();
    let fp_m = fp_model(&cfg, 29);
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 2, 12, 2));
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let obs = Obs::new();
    let server = Server::start_native_obs(set, policy, SchedConfig::default(), &obs).unwrap();
    for i in 0..3 {
        server.score("fp", window(i, 12, cfg.vocab)).unwrap();
    }
    server.shutdown();
    let total: usize = obs.recorder.snapshot().iter().map(|(_, _, r)| r.len()).sum();
    assert_eq!(total, 0, "disabled recorder must not retain events");
}

/// Prometheus exposition golden test: after a served load over fp +
/// a fast-mode quantized variant, every serving family renders with
/// `# HELP` / `# TYPE` headers, counters carry the exact request
/// counts, histograms expose cumulative buckets with a `+Inf` bound,
/// the kernel-path selection lands in the labeled fallback counter
/// and the trace, and the JSON snapshot parses back.
#[test]
fn prometheus_exposition_contains_serving_families() {
    use gsr::model::KernelMode;
    use gsr::quant::quantize_native_plan;

    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 31);
    let fp_m = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() });
    let plan = RotationPlan::uniform(
        RotationSpec {
            r1: R1Kind::GSR,
            r1_block: cfg.group,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0,
        },
        cfg.n_layers,
        7,
    );
    let rots = build_plan_rotations(&cfg, &plan).unwrap();
    let (mut qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
    qp.kernels = KernelMode::Fast;
    let q_m = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None });
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::new(Arc::clone(&fp_m), 2, 12, 2));
    set.insert("q", NativeBackend::new(Arc::clone(&q_m), 2, 12, 2));
    let policy = BatchPolicy { max_batch: 2, max_wait: Duration::from_millis(2) };
    let obs = Obs::new();
    obs.recorder.enable();
    let server = Server::start_native_obs(set, policy, SchedConfig::default(), &obs).unwrap();
    for i in 0..3 {
        server.score("fp", window(i, 12, cfg.vocab)).unwrap();
    }
    assert!(server.score("nope", vec![1, 2]).is_err());
    server.shutdown();
    let text = obs.registry.expose_prometheus();
    for family in [
        "gsr_requests_total",
        "gsr_batches_total",
        "gsr_batch_rows_total",
        "gsr_tokens_total",
        "gsr_rejected_total",
        "gsr_generations_total",
        "gsr_preemptions_total",
        "gsr_kv_blocks",
        "gsr_dense_fallbacks",
        "gsr_request_latency_us",
        "gsr_exec_latency_us",
        "gsr_decode_latency_us",
    ] {
        assert!(text.contains(&format!("# HELP {family} ")), "missing HELP for {family}");
        assert!(text.contains(&format!("# TYPE {family} ")), "missing TYPE for {family}");
    }
    assert!(text.contains("gsr_requests_total 3"), "{text}");
    assert!(text.contains("gsr_batch_rows_total 3"), "{text}");
    assert!(
        text.contains("gsr_rejected_total{reason=\"unknown_variant\"} 1"),
        "labeled rejection cell missing:\n{text}"
    );
    assert!(text.contains("gsr_request_latency_us_count 3"), "{text}");
    assert!(text.contains("gsr_request_latency_us_bucket{le=\"+Inf\"} 3"), "{text}");
    assert!(text.contains("gsr_fast_variants 1"), "{text}");
    // Labels render sorted; the fast-mode variant gets a labeled cell.
    assert!(
        text.contains("gsr_dense_fallbacks_by_variant{mode=\"fast\",variant=\"q\"}"),
        "kernel-path cell missing:\n{text}"
    );
    // The selection also lands in the trace, with its fallback count.
    let kernel_events: Vec<String> = obs
        .recorder
        .snapshot()
        .iter()
        .flat_map(|(_, _, r)| r.iter())
        .filter_map(|rec| match &rec.event {
            TraceEvent::KernelPath { variant, mode, .. } => Some(format!("{variant}/{mode}")),
            _ => None,
        })
        .collect();
    assert_eq!(kernel_events, vec!["q/fast".to_string()], "one selection per quant variant");
    // The JSON snapshot is the same cells and parses back.
    let snap = obs.registry.snapshot_json().to_string_pretty();
    let back = Json::parse(&snap).unwrap();
    let requests = back.at("gsr_requests_total").unwrap();
    let value = requests.at("values").unwrap().as_arr().unwrap()[0]
        .at("value")
        .unwrap()
        .as_f64()
        .unwrap();
    assert_eq!(value as u64, 3);
}

/// The same deterministic weights as the quantizer's internal
/// outlier test: unit-variance rows scaled by `1/sqrt(C)` with γ
/// outliers injected into every layer's norm weights.
fn outlier_fp(cfg: &ModelCfg, seed: u64) -> FpParams {
    let mut rng = SplitMix64::new(seed);
    let mut dense = |c: usize, h: usize| -> Vec<f32> {
        (0..c * h).map(|_| (rng.next_normal() / (c as f64).sqrt()) as f32).collect()
    };
    let layers = (0..cfg.n_layers)
        .map(|_| {
            let mut ln1: Vec<f32> = (0..cfg.d_model).map(|i| 1.0 + 0.1 * (i % 5) as f32).collect();
            let mut ln2: Vec<f32> =
                (0..cfg.d_model).map(|i| 1.0 + 0.05 * (i % 7) as f32).collect();
            // Outlier γ rows (the massive-channel substitution).
            ln1[3] = 9.0;
            ln1[17] = 12.0;
            ln2[8] = 10.0;
            FpLayer {
                ln1,
                ln2,
                wq: dense(cfg.d_model, cfg.d_model),
                wk: dense(cfg.d_model, cfg.d_model),
                wv: dense(cfg.d_model, cfg.d_model),
                wo: dense(cfg.d_model, cfg.d_model),
                wgate: dense(cfg.d_model, cfg.d_ffn),
                wup: dense(cfg.d_model, cfg.d_ffn),
                wdown: dense(cfg.d_ffn, cfg.d_model),
            }
        })
        .collect();
    FpParams {
        embed: dense(cfg.vocab, cfg.d_model),
        lm_head: dense(cfg.d_model, cfg.vocab),
        ln_f: vec![1.0; cfg.d_model],
        layers,
    }
}

fn telemetry_of(cfg: &ModelCfg, fp: &FpParams, spec: RotationSpec) -> Vec<LayerQuantTelemetry> {
    let plan = RotationPlan::uniform(spec, cfg.n_layers, 13);
    let rots = build_plan_rotations(cfg, &plan).unwrap();
    let (_, _, _, layers) = quantize_native_plan_telemetry(fp, cfg, &rots, 2, None).unwrap();
    layers
}

/// The paper's claim through the telemetry channel: on outlier-γ
/// weights, a uniform sequency-Walsh (GSR) plan reports per-layer
/// proxy error no worse than the global standard-Hadamard plan — for
/// every layer, with each layer's chosen spec recorded faithfully.
#[test]
fn per_layer_telemetry_shows_gsr_error_at_most_hadamard() {
    let cfg = tiny_cfg();
    let fp = outlier_fp(&cfg, 11);
    let gsr = telemetry_of(
        &cfg,
        &fp,
        RotationSpec {
            r1: R1Kind::GSR,
            r1_block: cfg.group,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0,
        },
    );
    let gh = telemetry_of(
        &cfg,
        &fp,
        RotationSpec {
            r1: R1Kind::GH,
            r1_block: cfg.d_model,
            r4: R4Kind::GH,
            r4_block: cfg.d_ffn,
            r1_angles: 0,
        },
    );
    assert_eq!(gsr.len(), cfg.n_layers, "one telemetry entry per layer");
    assert_eq!(gh.len(), cfg.n_layers);
    for (a, b) in gsr.iter().zip(&gh) {
        assert_eq!(a.layer, b.layer);
        assert!(a.spec.label().contains("GSR"), "recorded spec: {}", a.spec.label());
        assert!(b.spec.label().contains("GH"), "recorded spec: {}", b.spec.label());
        assert!(a.weights == b.weights && a.weights > 0);
        assert!(
            a.sse <= b.sse,
            "layer {}: GSR sse {:.2} must not exceed GH sse {:.2}",
            a.layer,
            a.sse,
            b.sse
        );
        assert!(a.mse() > 0.0 && a.max_abs_weight > 0.0 && a.rms_weight > 0.0);
    }
    let total_gsr: f64 = gsr.iter().map(|t| t.sse).sum();
    let total_gh: f64 = gh.iter().map(|t| t.sse).sum();
    assert!(total_gsr < total_gh, "aggregate: GSR {total_gsr:.2} vs GH {total_gh:.2}");
}
