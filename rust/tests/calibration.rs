//! End-to-end tests of the calibration subsystem: capture → artifact →
//! calibrated GPTQ → eval, plus the basis-fingerprint safety rails.
//! Pure native (no PJRT, no prebuilt artifacts).

use std::path::PathBuf;

use gsr::calib::{capture_hessians, checkpoint_fingerprint, CaptureKey, HessianSet};
use gsr::data::{draw_token_windows, CorpusGenerator, SEED_CORPUS};
use gsr::eval::PplEngine;
use gsr::exec::NativeBackend;
use gsr::model::config::LINEARS;
use gsr::model::{DenseModel, FpParams, ModelCfg};
use gsr::quant::{
    build_plan_rotations, fuse_rotations_plan, fuse_to_dense_plan, quantize_native_plan,
    quantize_native_plan_with, QuantizedLinear, RotationPlan, RotationSpec,
};
use gsr::search::{search_plan_calibrated, CalibWeights, GridCfg, SearchCfg};
use gsr::transform::{Mat, R1Kind};

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

/// Shared fixture: checkpoint, baseline plan, fused dense params, and a
/// Hessian set captured on the calibration split.
struct Fixture {
    cfg: ModelCfg,
    fp: FpParams,
    plan: RotationPlan,
    set: HessianSet,
    eval_split: Vec<u8>,
}

fn fixture() -> Fixture {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 11);
    let corpus = CorpusGenerator::new(SEED_CORPUS).generate(24_000);
    let (calib_split, eval_split) = corpus.split_at(16_000);
    let plan = RotationPlan::uniform(RotationSpec::baseline(&cfg), cfg.n_layers, 2025);
    let rots = build_plan_rotations(&cfg, &plan).unwrap();
    let dense = fuse_to_dense_plan(&fp, &cfg, &rots);
    let seqs = draw_token_windows(calib_split, 24, 48, cfg.vocab, 0xCA11B);
    let key = CaptureKey {
        calib_seed: 0xCA11B,
        basis_fingerprint: plan.fingerprint(),
        checkpoint_fingerprint: checkpoint_fingerprint(&fp),
        plan_json: plan.to_json().to_string_pretty(),
    };
    let set = capture_hessians(&cfg, &dense, &seqs, 0, &key);
    Fixture { cfg, fp, plan, set, eval_split: eval_split.to_vec() }
}

fn ppl_of(cfg: &ModelCfg, params: gsr::model::QuantParams, text: &[u8]) -> f64 {
    let tokens: Vec<u8> = text.iter().map(|&b| b % cfg.vocab as u8).collect();
    let model = DenseModel::Quant { cfg: cfg.clone(), params, a_bits: None };
    let native = NativeBackend::new(std::sync::Arc::new(model), 4, 48, 2);
    PplEngine::new(40).evaluate(&native, &tokens).unwrap().ppl
}

/// The acceptance property: with real Hessians from corpus activations,
/// GPTQ produces a model whose perplexity on the held-out synthetic eval
/// split is no worse than the identity-Hessian pipeline's (small
/// multiplicative slack for fp jitter; in practice the gap is real).
#[test]
fn calibrated_ppl_no_worse_than_identity_on_synthetic_eval() {
    let fx = fixture();
    let rots = build_plan_rotations(&fx.cfg, &fx.plan).unwrap();
    let (qp_id, _, _) = quantize_native_plan(&fx.fp, &fx.cfg, &rots, 2);
    let (qp_cal, _, _) =
        quantize_native_plan_with(&fx.fp, &fx.cfg, &rots, 2, Some(&fx.set)).unwrap();
    let ppl_id = ppl_of(&fx.cfg, qp_id, &fx.eval_split);
    let ppl_cal = ppl_of(&fx.cfg, qp_cal, &fx.eval_split);
    assert!(
        ppl_cal.is_finite() && ppl_id.is_finite(),
        "non-finite PPL: calibrated {ppl_cal}, identity {ppl_id}"
    );
    assert!(
        ppl_cal <= ppl_id * 1.02,
        "calibrated GPTQ PPL {ppl_cal:.3} worse than identity-Hessian PPL {ppl_id:.3}"
    );
}

/// The quantity calibrated GPTQ actually minimizes — reconstruction
/// error on the calibration inputs themselves, `Σ tr(ΔWᵀ H ΔW)` over
/// every linear — must not regress versus identity-Hessian GPTQ.
#[test]
fn calibrated_gptq_cuts_reconstruction_error_on_calib_inputs() {
    let fx = fixture();
    let rots = build_plan_rotations(&fx.cfg, &fx.plan).unwrap();
    let (_, _, ql_id) = quantize_native_plan(&fx.fp, &fx.cfg, &rots, 2);
    let (_, _, ql_cal) =
        quantize_native_plan_with(&fx.fp, &fx.cfg, &rots, 2, Some(&fx.set)).unwrap();
    let (_, _, fused, _) = fuse_rotations_plan(&fx.fp, &fx.cfg, &rots);

    let hessian_loss = |qlinears: &[QuantizedLinear]| -> f64 {
        let mut total = 0.0;
        for (l, map) in fused.iter().enumerate() {
            for (i, name) in LINEARS.iter().enumerate() {
                let w = &map[*name];
                let q = &qlinears[l * LINEARS.len() + i];
                let deq = q.dequant();
                let dw = Mat::from_fn(w.rows, w.cols, |r, c| deq[(r, c)] - w[(r, c)]);
                let h = fx.set.hessian_mat(l, name);
                let hdw = h.matmul(&dw);
                total += dw.data.iter().zip(&hdw.data).map(|(a, b)| a * b).sum::<f64>();
            }
        }
        total
    };
    let loss_id = hessian_loss(&ql_id);
    let loss_cal = hessian_loss(&ql_cal);
    assert!(loss_id.is_finite() && loss_cal.is_finite());
    assert!(
        loss_cal <= loss_id * 1.01 + 1e-9,
        "calibrated ‖XΔW‖² {loss_cal:.4} regressed vs identity {loss_id:.4}"
    );
}

/// The artifact is reusable: save → load → quantize must agree exactly
/// with quantizing from the in-memory capture.
#[test]
fn hessian_artifact_reuse_is_exact() {
    let fx = fixture();
    let path: PathBuf = std::env::temp_dir().join("gsr_calibration_reuse_test.bin");
    fx.set.save(&path).unwrap();
    let reloaded = HessianSet::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    assert_eq!(fx.set, reloaded);
    assert_eq!(reloaded.basis_fingerprint, fx.plan.fingerprint());

    let rots = build_plan_rotations(&fx.cfg, &fx.plan).unwrap();
    let (qp_a, sse_a, _) =
        quantize_native_plan_with(&fx.fp, &fx.cfg, &rots, 2, Some(&fx.set)).unwrap();
    let (qp_b, sse_b, _) =
        quantize_native_plan_with(&fx.fp, &fx.cfg, &rots, 2, Some(&reloaded)).unwrap();
    assert_eq!(sse_a.to_bits(), sse_b.to_bits());
    for (la, lb) in qp_a.layers.iter().zip(&qp_b.layers) {
        for name in LINEARS {
            assert_eq!(la.dense[name], lb.dense[name], "{name} dequant drifted");
        }
    }
}

/// Basis fingerprints fence misuse: Hessians captured under one rotation
/// basis refuse to serve another.
#[test]
fn fingerprint_guards_against_basis_mismatch() {
    let fx = fixture();
    assert!(fx.set.check_basis(fx.plan.fingerprint()).is_ok());
    let mut other = fx.plan.clone();
    other.layers[0] = RotationSpec {
        r1: R1Kind::LH,
        r1_block: 8,
        r4: fx.plan.layers[0].r4,
        r4_block: fx.plan.layers[0].r4_block,
        r1_angles: 0,
    };
    assert_ne!(other.fingerprint(), fx.plan.fingerprint());
    assert!(fx.set.check_basis(other.fingerprint()).is_err());
    // Checkpoint identity is the third key component: same geometry,
    // different weights → refused.
    let other_fp = FpParams::synthetic(&fx.cfg, 12);
    assert!(fx.set.check_checkpoint(&fx.fp).is_ok());
    assert!(fx.set.check_checkpoint(&other_fp).is_err());
}

/// `gsr search --calib` end to end: weights from a reloaded artifact
/// drive the diag(H)-weighted objective; the searched plan stays valid
/// and never loses to the fixed-GSR baseline under that objective.
#[test]
fn calibrated_search_from_artifact_end_to_end() {
    let fx = fixture();
    let path: PathBuf = std::env::temp_dir().join("gsr_calibration_search_test.bin");
    fx.set.save(&path).unwrap();
    let reloaded = HessianSet::load(&path).unwrap();
    let _ = std::fs::remove_file(&path);
    let calib = CalibWeights::from_hessian_set(&reloaded, &fx.cfg).unwrap();
    assert_eq!(calib.tokens, fx.set.tokens);
    let scfg = SearchCfg {
        grid: GridCfg {
            r1_kinds: vec![R1Kind::GH, R1Kind::GSR, R1Kind::LH],
            blocks: vec![8, 16, 32],
            r4_kinds: vec![gsr::model::R4Kind::GH, gsr::model::R4Kind::LH],
        },
        threads: 2,
        ..SearchCfg::default()
    };
    let out = search_plan_calibrated(&fx.fp, &fx.cfg, &scfg, Some(&calib)).unwrap();
    for l in &out.layers {
        assert!(
            l.best.quant_mse <= l.baseline.quant_mse,
            "layer {}: {} > baseline {}",
            l.layer,
            l.best.quant_mse,
            l.baseline.quant_mse
        );
        assert!(l.evaluated > 1, "grid must actually be explored");
    }
    build_plan_rotations(&fx.cfg, &out.plan).expect("searched plan must build");
}
