//! End-to-end tests over the PJRT runtime and the AOT artifacts.
//!
//! These require `make artifacts` to have run; each test skips (with a
//! note) when the artifact directory is missing so `cargo test` stays
//! green on a fresh clone.

use std::path::Path;

use gsr::coordinator::{BatchPolicy, Server};
use gsr::eval::{EvalOpts, PplEngine};
use gsr::exec::{NativeBackend, PjrtBackend};
use gsr::model::{DenseModel, FpParams, QuantParams};
use gsr::runtime::{Artifacts, Engine, VariantRunner};

fn artifacts() -> Option<Artifacts> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Artifacts::load(dir).ok()
}

/// PJRT fp graph ≡ native Rust forward on the same weights.
#[test]
fn pjrt_matches_native_reference_fp() {
    let Some(arts) = artifacts() else { return };
    let mut engine = Engine::new().expect("pjrt cpu client");
    let runner = VariantRunner::load_fp(&mut engine, &arts).expect("load fp");
    let fp = FpParams::load(&arts.fp_weights_path(), &arts.cfg).expect("fp blob");
    let native = DenseModel::Fp { cfg: arts.cfg.clone(), params: fp };

    // One [B, T] batch of corpus tokens.
    let (b, s, v) = (arts.batch, arts.seq, arts.cfg.vocab);
    let text = &arts.test_split()[..b * s];
    let tokens: Vec<i32> = text.iter().map(|&x| x as i32).collect();
    let pjrt_logits = runner.forward(&engine, &tokens).expect("execute");
    assert_eq!(pjrt_logits.len(), b * s * v);

    for row in 0..b {
        let native_logits = native.forward(&tokens[row * s..(row + 1) * s]);
        let pj = &pjrt_logits[row * s * v..(row + 1) * s * v];
        let mut worst = 0f32;
        for (a, g) in pj.iter().zip(&native_logits) {
            worst = worst.max((a - g).abs());
        }
        assert!(
            worst < 2e-2,
            "row {row}: PJRT vs native fp divergence {worst}"
        );
    }
}

/// PJRT quantized graph ≡ native rotated/quantized forward.
#[test]
fn pjrt_matches_native_reference_quant() {
    let Some(arts) = artifacts() else { return };
    let Some(meta) = arts.variant("quarot_w2a16_gsr_r4gh").cloned() else {
        eprintln!("skipping: variant not built");
        return;
    };
    let mut engine = Engine::new().unwrap();
    let runner = VariantRunner::load(&mut engine, &arts, &meta).expect("load variant");
    let qp = QuantParams::load(&arts.weights_path(&meta), &arts.cfg, meta.r4_kind())
        .expect("decode variant blob");
    let native = DenseModel::Quant {
        cfg: arts.cfg.clone(),
        params: qp,
        a_bits: meta.a_bits(),
    };
    let (b, s, v) = (arts.batch, arts.seq, arts.cfg.vocab);
    let text = &arts.test_split()[1000..1000 + b * s];
    let tokens: Vec<i32> = text.iter().map(|&x| x as i32).collect();
    let pjrt_logits = runner.forward(&engine, &tokens).unwrap();
    for row in 0..b.min(2) {
        let native_logits = native.forward(&tokens[row * s..(row + 1) * s]);
        let pj = &pjrt_logits[row * s * v..(row + 1) * s * v];
        let mut worst = 0f32;
        for (a, g) in pj.iter().zip(&native_logits) {
            worst = worst.max((a - g).abs());
        }
        assert!(
            worst < 5e-2,
            "row {row}: PJRT vs native quant divergence {worst}"
        );
    }
}

/// PPL through PJRT and through the native model agree closely, and the
/// quantized model is worse than fp (sanity of the whole eval stack).
#[test]
fn ppl_pjrt_vs_native_and_fp_ordering() {
    let Some(arts) = artifacts() else { return };
    let mut engine = Engine::new().unwrap();
    let fp_runner = VariantRunner::load_fp(&mut engine, &arts).unwrap();
    let engine_ref = &engine;
    let fp_model = PjrtBackend { engine: engine_ref, runner: &fp_runner };
    let ppl_engine = PplEngine::new(6);
    let fp_ppl = ppl_engine.evaluate(&fp_model, arts.test_split()).unwrap().ppl;

    let fp = FpParams::load(&arts.fp_weights_path(), &arts.cfg).unwrap();
    let native = DenseModel::Fp { cfg: arts.cfg.clone(), params: fp };
    let native_model =
        NativeBackend::new(std::sync::Arc::new(native), arts.batch, arts.seq, 0);
    let native_ppl = ppl_engine.evaluate(&native_model, arts.test_split()).unwrap().ppl;
    assert!(
        (fp_ppl - native_ppl).abs() / native_ppl < 0.02,
        "fp PPL {fp_ppl} vs native {native_ppl}"
    );

    if let Some(meta) = arts.variant("quarot_w2a16_gh_r4gh").cloned() {
        let qrunner = VariantRunner::load(&mut engine, &arts, &meta).unwrap();
        let qmodel = PjrtBackend { engine: &engine, runner: &qrunner };
        let qppl = PplEngine::new(6).evaluate(&qmodel, arts.test_split()).unwrap().ppl;
        assert!(
            qppl > fp_ppl,
            "W2 model ({qppl}) must be worse than fp ({fp_ppl})"
        );
    }
}

/// The batching server round-trips requests and accounts for them.
#[test]
fn server_roundtrip_and_metrics() {
    let Some(arts) = artifacts() else { return };
    let server = Server::start(
        Path::new("artifacts"),
        &["fp".to_string()],
        BatchPolicy::default(),
    )
    .expect("server start");
    let seq = arts.seq;
    let text = arts.test_split();
    let n = 6;
    for i in 0..n {
        let tokens: Vec<i32> = text[i * 13..i * 13 + seq].iter().map(|&b| b as i32).collect();
        let logits = server.score("fp", tokens).expect("score");
        assert_eq!(logits.len(), seq * arts.cfg.vocab);
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    // Unknown variant surfaces as a routed error, not a hang.
    let err = server.score("not-a-variant", vec![1, 2, 3]);
    assert!(err.is_err());
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, n as u64);
    assert!(metrics.batches >= 1);
}

/// Full eval convenience path used by the tables.
#[test]
fn eval_variant_smoke() {
    let Some(arts) = artifacts() else { return };
    let mut engine = Engine::new().unwrap();
    let opts = EvalOpts { windows: 3, tasks_per_kind: 2 };
    let ev = gsr::eval::tables::eval_variant(&mut engine, &arts, "fp", opts).unwrap();
    assert!(ev.ppl.is_finite() && ev.ppl > 1.0);
    assert!(ev.zero_shot_avg >= 0.0 && ev.zero_shot_avg <= 100.0);
    assert_eq!(ev.per_task.len(), 8);
}
