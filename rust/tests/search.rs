//! Search-correctness suite for `gsr search` over the expanded
//! candidate space (Givens chains + butterfly factorizations) and both
//! Hessian proxies. Pins, through the public API only:
//!
//! * grid shape: fixed-GSR baseline at slot 0, no duplicate canonical
//!   specs, parametric candidates seeded at their default angles;
//! * baseline unbeatability: under the diag proxy, the calibrated diag
//!   proxy, and the full-Hessian proxy, every layer's chosen spec scores
//!   ≤ the fixed-GSR baseline scored under the same objective;
//! * determinism: the same (checkpoint, corpus, seed) search — angle
//!   coordinate descent included — emits the identical plan and
//!   fingerprint at any thread count and across reruns;
//! * persistence: a searched plan with parametric winners survives the
//!   plan-JSON round-trip losslessly and rebuilds bit-identical rotation
//!   matrices from the spec alone.

use gsr::calib::{capture_hessians, checkpoint_fingerprint, CaptureKey};
use gsr::config::Json;
use gsr::data::{draw_token_windows, CorpusGenerator};
use gsr::model::{FpParams, ModelCfg, R4Kind};
use gsr::quant::{build_plan_rotations, fuse_to_dense_plan, RotationPlan, RotationSpec};
use gsr::search::{
    candidate_grid, search_plan, search_plan_calibrated, CalibWeights, GridCfg, ProxyKind,
    SearchCfg,
};
use gsr::transform::{default_angles, mask_angles, R1Kind};

fn tiny_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ffn: 64,
        group: 16,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

/// The expanded grid under test: the paper's fixed GSR plus both
/// parametric families, two block sizes, one R4 kind (small enough for
/// an integration sweep, rich enough that descent actually runs).
fn expanded_grid() -> GridCfg {
    GridCfg {
        r1_kinds: vec![R1Kind::GSR, R1Kind::GIV, R1Kind::BFLY],
        blocks: vec![8, 16],
        r4_kinds: vec![R4Kind::GH],
    }
}

/// Capture a small Hessian set in the fixed-GSR baseline basis — the
/// exact flow `gsr calibrate --synthetic` runs, shrunk for test time.
fn captured(cfg: &ModelCfg, fp: &FpParams, seed: u64) -> CalibWeights {
    let plan = RotationPlan::uniform(RotationSpec::baseline(cfg), cfg.n_layers, seed);
    let rots = build_plan_rotations(cfg, &plan).unwrap();
    let dense = fuse_to_dense_plan(fp, cfg, &rots);
    let corpus = CorpusGenerator::new(29).generate(2048);
    let seqs = draw_token_windows(&corpus, 6, 12, cfg.vocab, 3);
    let key = CaptureKey {
        calib_seed: 3,
        basis_fingerprint: plan.fingerprint(),
        checkpoint_fingerprint: checkpoint_fingerprint(fp),
        plan_json: plan.to_json().to_string_pretty(),
    };
    let set = capture_hessians(cfg, &dense, &seqs, 0, &key);
    CalibWeights::from_hessian_set(&set, cfg).unwrap()
}

/// Expanded grid shape: baseline first and unique, every spec canonical
/// and distinct, parametric entries present for both families at both
/// blocks and seeded at their default angle word.
#[test]
fn expanded_grid_baseline_slot_zero_and_no_duplicates() {
    let cfg = tiny_cfg();
    let grid = candidate_grid(&cfg, &expanded_grid());
    let baseline = RotationSpec::baseline(&cfg).canonical(&cfg);
    assert_eq!(grid[0], baseline, "fixed-GSR baseline must occupy slot 0");
    for (i, a) in grid.iter().enumerate() {
        for (j, b) in grid.iter().enumerate().skip(i + 1) {
            assert_ne!(a, b, "slots {i} and {j} duplicate: {}", a.label());
        }
        assert_eq!(*a, a.canonical(&cfg), "slot {i} not canonical");
    }
    for kind in [R1Kind::GIV, R1Kind::BFLY] {
        for block in [8usize, 16] {
            let spec = grid
                .iter()
                .find(|s| s.r1 == kind && s.r1_block == block)
                .unwrap_or_else(|| panic!("{kind}/{block} missing from expanded grid"));
            assert_eq!(spec.r1_angles, default_angles(kind, block));
        }
    }
}

/// Baseline unbeatability under every objective the CLI can select:
/// uncalibrated diag, calibrated diag, and calibrated full. The same
/// checkpoint is searched three ways; each way, every layer's winner
/// scores ≤ the fixed-GSR baseline under that run's own proxy.
#[test]
fn no_proxy_ever_loses_to_fixed_gsr() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 41);
    let base = SearchCfg { grid: expanded_grid(), threads: 2, ..SearchCfg::default() };
    let calib = captured(&cfg, &fp, base.seed);
    let runs = [
        ("diag", search_plan(&fp, &cfg, &base).unwrap()),
        (
            "diag+calib",
            search_plan_calibrated(&fp, &cfg, &base, Some(&calib)).unwrap(),
        ),
        (
            "full+calib",
            search_plan_calibrated(
                &fp,
                &cfg,
                &SearchCfg { proxy: ProxyKind::Full, ..base.clone() },
                Some(&calib),
            )
            .unwrap(),
        ),
    ];
    for (label, out) in &runs {
        assert_eq!(out.plan.layers.len(), cfg.n_layers, "{label}");
        for l in &out.layers {
            assert!(
                l.best.quant_mse <= l.baseline.quant_mse,
                "{label} layer {}: searched {} > baseline {}",
                l.layer,
                l.best.quant_mse,
                l.baseline.quant_mse
            );
            assert!(l.best.quant_mse.is_finite(), "{label} layer {}", l.layer);
        }
        build_plan_rotations(&cfg, &out.plan)
            .unwrap_or_else(|e| panic!("{label}: searched plan must build: {e}"));
    }
}

/// Determinism of the full search — angle coordinate descent included:
/// the same (checkpoint, corpus, seed) run emits the identical plan and
/// fingerprint at thread counts 1 and 3 and across a rerun, for both
/// proxies.
#[test]
fn search_is_deterministic_across_threads_and_reruns() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 43);
    let calib = captured(&cfg, &fp, SearchCfg::default().seed);
    for proxy in [ProxyKind::Diag, ProxyKind::Full] {
        let mk = |threads: usize| {
            let scfg =
                SearchCfg { grid: expanded_grid(), threads, proxy, ..SearchCfg::default() };
            search_plan_calibrated(&fp, &cfg, &scfg, Some(&calib)).unwrap()
        };
        let a = mk(1);
        let b = mk(3);
        assert_eq!(a.plan, b.plan, "{proxy:?}: thread count changed the plan");
        assert_eq!(
            a.plan.fingerprint(),
            mk(1).plan.fingerprint(),
            "{proxy:?}: rerun changed the plan"
        );
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(
                x.best.quant_mse.to_bits(),
                y.best.quant_mse.to_bits(),
                "{proxy:?} layer {}: score depends on thread count",
                x.layer
            );
        }
    }
}

/// A searched plan whose layers carry parametric (angle-bearing)
/// winners round-trips through plan JSON losslessly — same specs, same
/// fingerprint — and the reloaded plan rebuilds **bit-identical**
/// rotation matrices, because parametric builds are pure functions of
/// the spec and seeded builds are keyed on (spec, plan seed).
#[test]
fn searched_parametric_plan_roundtrips_and_rebuilds_bit_identically() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 47);
    // Force parametric winners: a grid of only GIV/BFLY still keeps the
    // injected baseline at slot 0, so winners beat it or tie it.
    let scfg = SearchCfg {
        grid: GridCfg {
            r1_kinds: vec![R1Kind::GIV, R1Kind::BFLY],
            blocks: vec![8, 16],
            r4_kinds: vec![R4Kind::GH],
        },
        threads: 2,
        ..SearchCfg::default()
    };
    let out = search_plan(&fp, &cfg, &scfg).unwrap();
    for s in &out.plan.layers {
        if s.r1.is_parametric() {
            assert_eq!(
                s.r1_angles,
                mask_angles(s.r1, s.r1_block, s.r1_angles),
                "winner {} carries dead angle bytes",
                s.label()
            );
        }
    }
    let text = out.plan.to_json().to_string_pretty();
    let reloaded = RotationPlan::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(reloaded, out.plan, "plan JSON round-trip must be lossless");
    assert_eq!(reloaded.fingerprint(), out.plan.fingerprint());
    let a = build_plan_rotations(&cfg, &out.plan).unwrap();
    let b = build_plan_rotations(&cfg, &reloaded).unwrap();
    for (l, (x, y)) in a.layers.iter().zip(&b.layers).enumerate() {
        assert_eq!(x.spec, y.spec, "layer {l}");
        assert_eq!(x.r1.data, y.r1.data, "layer {l}: R1 rebuild drifted");
        assert_eq!(x.r4.data, y.r4.data, "layer {l}: R4 rebuild drifted");
    }
}

/// `--proxy full` without a calibration artifact is a loud error, not a
/// silent fallback to some other objective.
#[test]
fn full_proxy_requires_calibration() {
    let cfg = tiny_cfg();
    let fp = FpParams::synthetic(&cfg, 53);
    let scfg =
        SearchCfg { grid: expanded_grid(), proxy: ProxyKind::Full, ..SearchCfg::default() };
    let err = search_plan(&fp, &cfg, &scfg).unwrap_err();
    assert!(err.contains("--calib"), "unhelpful error: {err}");
}
