//! Cross-module integration tests (no PJRT — see runtime_e2e.rs for the
//! artifact-dependent end-to-end path).

use std::path::Path;

use gsr::analysis::{outlier_spread, sequency_variance_report};
use gsr::data::tasks::TaskSuite;
use gsr::data::{ByteTokenizer, CorpusGenerator, SEED_CORPUS};
use gsr::eval::{log_softmax_nll, PplEngine, ZeroShotEngine};
use gsr::exec::Backend;
use gsr::quant::{gptq_quantize, rtn_quantize};
use gsr::rng::SplitMix64;
use gsr::transform::{build_r1, Mat, R1Kind};

/// Corpus generator must reproduce the Python-written artifact exactly.
/// (Skips silently if `make artifacts` has not run yet.)
#[test]
fn corpus_matches_python_artifact() {
    let path = Path::new("artifacts/corpus.bin");
    if !path.exists() {
        eprintln!("skipping: artifacts/corpus.bin not built");
        return;
    }
    let expect = std::fs::read(path).unwrap();
    let got = CorpusGenerator::new(SEED_CORPUS).generate(expect.len());
    assert_eq!(
        got, expect,
        "Rust corpus generator diverged from the Python artifact"
    );
}

/// Manifest parses and the locally-computed param specs agree with it.
#[test]
fn manifest_specs_match_native_mirror() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let arts = gsr::runtime::Artifacts::load(dir).unwrap();
    let cfg = &arts.cfg;
    // fp spec
    let manifest_fp = arts.graph_spec("fp").unwrap();
    let native_fp = cfg.fp_param_spec();
    assert_eq!(manifest_fp.len(), native_fp.len());
    for (m, n) in manifest_fp.iter().zip(&native_fp) {
        assert_eq!(m.name, n.name);
        assert_eq!(m.shape, n.shape);
    }
    // quant specs
    for (graph, r4) in [
        ("w2a16_r4gh", gsr::model::R4Kind::GH),
        ("w2a4_r4lh", gsr::model::R4Kind::LH),
    ] {
        let manifest_q = arts.graph_spec(graph).unwrap();
        let native_q = cfg.quant_param_spec(r4);
        assert_eq!(manifest_q.len(), native_q.len(), "{graph}");
        for (m, n) in manifest_q.iter().zip(&native_q) {
            assert_eq!(m.name, n.name, "{graph}");
            assert_eq!(m.shape, n.shape, "{graph} {}", m.name);
        }
    }
}

/// The §3.2 claim end-to-end on structured weights: sequency variance of
/// the rotation's column groups orders GH > GW, and local variants
/// confine outliers (Fig. 2) — the two mechanisms behind Table 1.
#[test]
fn analysis_reproduces_paper_mechanisms() {
    let reports = sequency_variance_report(256, 64, 48, 2, 123);
    let get = |k: R1Kind| reports.iter().find(|r| r.kind == k).unwrap();
    assert!(
        get(R1Kind::GW).mean_group_variance < get(R1Kind::GH).mean_group_variance,
        "Walsh ordering must reduce intra-group sequency variance"
    );
    assert!(
        get(R1Kind::GSR).mean_group_variance <= get(R1Kind::LH).mean_group_variance,
        "GSR blocks are sequency-ordered, LH blocks are not"
    );
    let spreads = outlier_spread(256, 64, 7);
    let sp = |k: R1Kind| spreads.iter().find(|s| s.kind == k).unwrap();
    assert!(sp(R1Kind::GSR).in_group_energy > 0.99);
    assert!(sp(R1Kind::GH).in_group_energy < 0.5);
}

/// GPTQ + rotation stack on a structured weight: every rotation beats
/// no rotation under outlier rows, and the quantizers compose.
#[test]
fn rotation_plus_gptq_pipeline_native() {
    let mut rng = SplitMix64::new(9);
    let (c, h, group) = (128, 32, 32);
    // Structured weight with outlier input channels (γ-fold analogue).
    let mut w = Mat::from_fn(c, h, |_, _| rng.next_normal() * 0.1);
    for r in (0..c).step_by(17) {
        for col in 0..h {
            w[(r, col)] *= 9.0;
        }
    }
    let ident_err = rtn_quantize(&w, 2, group, true).mse(&w);
    for kind in R1Kind::ALL {
        let mut krng = SplitMix64::new(55);
        let r1 = build_r1(kind, c, group, &mut krng);
        let rotated = r1.transpose().matmul(&w);
        let q = rtn_quantize(&rotated, 2, group, true);
        let rot_err = q.mse(&rotated);
        assert!(
            rot_err < ident_err,
            "{kind}: rotated error {rot_err} should beat identity {ident_err}"
        );
        // And GPTQ must compose with the rotation (identity Hessian).
        let qg = gptq_quantize(&rotated, &Mat::identity(c), 2, group, true);
        assert!(qg.mse(&rotated) <= rot_err * 1.2);
    }
}

/// Tokenizer windows + PPL engine compose with a synthetic model.
#[test]
fn ppl_engine_with_tokenizer_windows() {
    struct Peaked;
    impl Backend for Peaked {
        fn batch(&self) -> usize {
            2
        }
        fn seq(&self) -> usize {
            16
        }
        fn vocab(&self) -> usize {
            256
        }
        fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
            // Predict "same byte again" with some confidence.
            let v = 256;
            let mut out = vec![0f32; tokens.len() * v];
            for (i, &t) in tokens.iter().enumerate() {
                out[i * v + t as usize] = 3.0;
            }
            Ok(out)
        }
    }
    // Long runs of a repeated byte → the "repeat" model scores well.
    let text = vec![b'a'; 400];
    let r = PplEngine::new(0).evaluate(&Peaked, &text).unwrap();
    assert!(r.ppl < 20.0, "repeat-predictor ppl {}", r.ppl);
    // Sanity vs analytic value: softmax(3 vs 255 zeros).
    let logits = {
        let mut l = vec![0f32; 256];
        l[b'a' as usize] = 3.0;
        l
    };
    let nll = log_softmax_nll(&logits, 256, &[b'a' as i32], 1);
    assert!((r.nll_sum / r.tokens as f64 - nll).abs() < 1e-6);

    let tok = ByteTokenizer;
    let ids = tok.encode(&text);
    assert_eq!(tok.windows(&ids, 16).len(), (400 - 1) / 16);
}

/// Task suite + zero-shot scorer: a corpus-bigram oracle beats chance;
/// a uniform model sits at the chance floor.
#[test]
fn zeroshot_chance_floor_and_oracle_ceiling() {
    struct Uniform;
    impl Backend for Uniform {
        fn batch(&self) -> usize {
            4
        }
        fn seq(&self) -> usize {
            64
        }
        fn vocab(&self) -> usize {
            256
        }
        fn forward_batch(&self, tokens: &[i32]) -> Result<Vec<f32>, String> {
            Ok(vec![0f32; tokens.len() * 256])
        }
    }
    let suite = TaskSuite::new(SEED_CORPUS).suite(24);
    let (_, avg) = ZeroShotEngine::score_suite(&Uniform, &suite).unwrap();
    // 6 four-way + 2 binary families → chance = (6*25 + 2*50)/8 = 31.25.
    // A uniform scorer has no signal; with ties broken by order it can
    // deviate, but must stay well below a skilled model.
    assert!(avg < 45.0, "uniform avg {avg}");
}
