#![allow(dead_code)]
//! Shared mini-bench harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with median/min reporting, and the
//! artifact-presence guard every PJRT bench needs.

use std::path::Path;
use std::time::{Duration, Instant};

/// Time `f` over `iters` runs after `warmup` runs; returns per-run stats.
pub fn time_it<T>(label: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Duration {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let min = samples[0];
    println!(
        "bench {label:40} median {median:>12?}  min {min:>12?}  ({iters} iters)"
    );
    median
}

/// Artifact guard: returns false (and prints a notice) when artifacts
/// are missing so `cargo bench` stays green on fresh clones.
pub fn require_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        println!("SKIPPED: artifacts/ not built — run `make artifacts` first");
        false
    }
}

/// Env-tunable eval options (keep CI fast, allow full runs).
pub fn eval_opts() -> gsr::eval::EvalOpts {
    let windows = std::env::var("GSR_BENCH_WINDOWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let tasks = std::env::var("GSR_BENCH_TASKS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    gsr::eval::EvalOpts { windows, tasks_per_kind: tasks }
}
