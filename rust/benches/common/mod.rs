#![allow(dead_code)]
//! Shared mini-bench harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with median/min reporting, and the
//! artifact-presence guard every PJRT bench needs.

use std::path::Path;
use std::time::{Duration, Instant};

use gsr::config::Json;
use gsr::model::{ModelCfg, R4Kind};
use gsr::quant::{RotationPlan, RotationSpec};
use gsr::transform::R1Kind;

/// The shared benchmark model geometry (d=128, 4 layers, byte vocab)
/// used by the serving/decoding throughput benches — one definition so
/// their tok/s numbers stay comparable.
pub fn bench_model_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 256,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ffn: 256,
        group: 64,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

/// A genuinely heterogeneous plan over [`bench_model_cfg`]: layer 1
/// switches both R1 and R4, so benches exercise the per-layer basis
/// change and online-R4 override paths.
pub fn bench_hetero_plan(cfg: &ModelCfg) -> RotationPlan {
    let base = RotationSpec::baseline(cfg);
    let mut layers = vec![base; cfg.n_layers];
    layers[1] =
        RotationSpec { r1: R1Kind::LH, r1_block: 32, r4: R4Kind::LH, r4_block: 64, r1_angles: 0 };
    RotationPlan { seed: 2025, layers }
}

/// Per-run timing stats from [`time_stats`]. With the small iteration
/// counts these benches use, `p99` degenerates to the slowest run —
/// still the right number to persist for regression diffing.
pub struct TimedStats {
    pub median: Duration,
    pub min: Duration,
    pub p99: Duration,
}

/// Time `f` over `iters` runs after `warmup` runs; returns per-run stats.
pub fn time_stats<T>(
    label: &str,
    warmup: usize,
    iters: usize,
    mut f: impl FnMut() -> T,
) -> TimedStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let min = samples[0];
    let p99_idx = ((samples.len() as f64 * 0.99).ceil() as usize).max(1) - 1;
    let p99 = samples[p99_idx.min(samples.len() - 1)];
    println!(
        "bench {label:40} median {median:>12?}  min {min:>12?}  ({iters} iters)"
    );
    TimedStats { median, min, p99 }
}

/// Median-only convenience wrapper around [`time_stats`].
pub fn time_it<T>(label: &str, warmup: usize, iters: usize, f: impl FnMut() -> T) -> Duration {
    time_stats(label, warmup, iters, f).median
}

/// A `Duration` as fractional microseconds, the unit all BENCH_*.json
/// summaries use for latencies.
pub fn us(d: Duration) -> f64 {
    d.as_secs_f64() * 1e6
}

/// The model-geometry block embedded in every bench summary so numbers
/// stay comparable across commits.
pub fn bench_config_json(cfg: &ModelCfg) -> Json {
    Json::obj(vec![
        ("vocab", Json::num(cfg.vocab as f64)),
        ("d_model", Json::num(cfg.d_model as f64)),
        ("n_layers", Json::num(cfg.n_layers as f64)),
        ("n_heads", Json::num(cfg.n_heads as f64)),
        ("d_ffn", Json::num(cfg.d_ffn as f64)),
        ("group", Json::num(cfg.group as f64)),
    ])
}

/// Persist a machine-readable run summary to `BENCH_<name>.json` in the
/// working directory. Failures warn instead of panicking so a read-only
/// checkout still benches.
pub fn write_bench_json(name: &str, summary: Json) {
    let path = format!("BENCH_{name}.json");
    match summary.to_file(Path::new(&path)) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("WARNING: could not write {path}: {e}"),
    }
}

/// Artifact guard: returns false (and prints a notice) when artifacts
/// are missing so `cargo bench` stays green on fresh clones.
pub fn require_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        println!("SKIPPED: artifacts/ not built — run `make artifacts` first");
        false
    }
}

/// Env-tunable eval options (keep CI fast, allow full runs).
pub fn eval_opts() -> gsr::eval::EvalOpts {
    let windows = std::env::var("GSR_BENCH_WINDOWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let tasks = std::env::var("GSR_BENCH_TASKS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    gsr::eval::EvalOpts { windows, tasks_per_kind: tasks }
}
