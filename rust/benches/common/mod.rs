#![allow(dead_code)]
//! Shared mini-bench harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timing with median/min reporting, and the
//! artifact-presence guard every PJRT bench needs.

use std::path::Path;
use std::time::{Duration, Instant};

use gsr::model::{ModelCfg, R4Kind};
use gsr::quant::{RotationPlan, RotationSpec};
use gsr::transform::R1Kind;

/// The shared benchmark model geometry (d=128, 4 layers, byte vocab)
/// used by the serving/decoding throughput benches — one definition so
/// their tok/s numbers stay comparable.
pub fn bench_model_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 256,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ffn: 256,
        group: 64,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

/// A genuinely heterogeneous plan over [`bench_model_cfg`]: layer 1
/// switches both R1 and R4, so benches exercise the per-layer basis
/// change and online-R4 override paths.
pub fn bench_hetero_plan(cfg: &ModelCfg) -> RotationPlan {
    let base = RotationSpec::baseline(cfg);
    let mut layers = vec![base; cfg.n_layers];
    layers[1] = RotationSpec { r1: R1Kind::LH, r1_block: 32, r4: R4Kind::LH, r4_block: 64 };
    RotationPlan { seed: 2025, layers }
}

/// Time `f` over `iters` runs after `warmup` runs; returns per-run stats.
pub fn time_it<T>(label: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Duration {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let min = samples[0];
    println!(
        "bench {label:40} median {median:>12?}  min {min:>12?}  ({iters} iters)"
    );
    median
}

/// Artifact guard: returns false (and prints a notice) when artifacts
/// are missing so `cargo bench` stays green on fresh clones.
pub fn require_artifacts() -> bool {
    if Path::new("artifacts/manifest.json").exists() {
        true
    } else {
        println!("SKIPPED: artifacts/ not built — run `make artifacts` first");
        false
    }
}

/// Env-tunable eval options (keep CI fast, allow full runs).
pub fn eval_opts() -> gsr::eval::EvalOpts {
    let windows = std::env::var("GSR_BENCH_WINDOWS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(16);
    let tasks = std::env::var("GSR_BENCH_TASKS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    gsr::eval::EvalOpts { windows, tasks_per_kind: tasks }
}
