//! Paged-serving saturation bench: mixed scoring + generation traffic
//! through the continuous-batching executor, with the block pool
//! deliberately undersized so the timed waves include admission,
//! block grants, preemption and recompute-on-resume — the scheduler's
//! real work, not just the decode math.
//!
//! Decode parity is asserted before any timing: greedy generations
//! through the paged scheduler must equal a full re-forward of the
//! growing prefix token for token, so the throughput numbers below are
//! for bit-reproducible serving, never for drifted outputs.
//!
//! No artifacts needed: runs on the synthetic checkpoint.

#[path = "common/mod.rs"]
mod common;

use std::sync::{mpsc, Arc};

use gsr::config::Json;
use gsr::coordinator::{BatchPolicy, GenerateRequest, Server};
use gsr::exec::{greedy_argmax, ExecPool, NativeBackend, NativeSet};
use gsr::model::{DenseModel, FpParams, ModelCfg};
use gsr::sched::{SamplingParams, SchedConfig};

/// Generations per timed wave (half greedy, half sampled).
const GENS_PER_WAVE: usize = 12;
/// Scoring requests interleaved into each wave.
const SCORES_PER_WAVE: usize = 8;

/// Greedy decode by full re-forward of the growing prefix — the
/// reference semantics the paged KV path must reproduce exactly.
fn reforward_greedy(model: &DenseModel, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let v = model.cfg().vocab;
    let mut seq = prompt.to_vec();
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let logits = model.forward(&seq);
        let tok = greedy_argmax(&logits[(seq.len() - 1) * v..]);
        out.push(tok);
        seq.push(tok);
    }
    out
}

fn prompt_for(i: usize, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|j| ((j * 7 + i * 31 + 1) % vocab) as i32).collect()
}

/// One saturation wave: submit every generation up front (so the
/// executor's rounds stay full), push scoring traffic through the same
/// queues, then drain every reply.
fn run_wave(
    server: &Server,
    cfg: &ModelCfg,
    wave_idx: usize,
    prompt_len: usize,
    max_new: usize,
    seq: usize,
) {
    let mut pending = Vec::new();
    for i in 0..GENS_PER_WAVE {
        let (reply, rx) = mpsc::channel();
        let sampling = if i % 2 == 0 {
            SamplingParams::greedy()
        } else {
            SamplingParams { temperature: 0.8, top_k: 32, top_p: 0.95, seed: i as u64 }
        };
        server
            .submit_generate(GenerateRequest {
                variant: "fp".to_string(),
                prompt: prompt_for(wave_idx * 64 + i, prompt_len, cfg.vocab),
                max_new,
                stop: None,
                sampling,
                stream: None,
                reply,
            })
            .expect("submit generate");
        pending.push(rx);
    }
    for i in 0..SCORES_PER_WAVE {
        let tokens = prompt_for(wave_idx * 64 + 32 + i, seq, cfg.vocab);
        server.score("fp", tokens).expect("score");
    }
    for (i, rx) in pending.into_iter().enumerate() {
        let out = rx.recv().expect("reply").result.expect("generation");
        assert_eq!(out.prompt_len, prompt_len, "wave {wave_idx} gen {i}");
    }
}

fn main() {
    let cfg = common::bench_model_cfg();
    let fp = FpParams::synthetic(&cfg, 7);
    let model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp });
    let (b, s) = (4usize, 96usize);
    let pool = Arc::new(ExecPool::new(0));
    let mut set = NativeSet::new();
    set.insert("fp", NativeBackend::with_pool(Arc::clone(&model), b, s, pool));
    let policy = BatchPolicy { max_batch: b, ..BatchPolicy::default() };
    // 24 blocks x 16 tokens = 384 pool tokens against a wave demanding
    // 12 x (48 + 16 - 1) = 756 at peak: admission accepts everything
    // (each request fits alone) and preemption keeps it live.
    let sched = SchedConfig { page_size: 16, kv_blocks: 24, prefill_chunk: 32, speculate: None };
    let server = Server::start_native_sched(set, policy, sched.clone()).expect("server start");

    // Decode-parity gate before any timing.
    let (prompt_len, max_new) = (48usize, 16usize);
    let parity_cases = 3;
    for i in 0..parity_cases {
        let prompt = prompt_for(i, prompt_len, cfg.vocab);
        let want = reforward_greedy(&model, &prompt, max_new);
        let got = server.generate("fp", prompt, max_new, None).expect("parity generation");
        assert_eq!(got.tokens, want, "paged greedy diverged from re-forward (case {i})");
    }
    println!("parity: paged greedy == full re-forward on {parity_cases} cases\n");

    let mut wave_idx = 0usize;
    let wave = common::time_stats("paged serve mixed wave", 1, 3, || {
        run_wave(&server, &cfg, wave_idx, prompt_len, max_new, s);
        wave_idx += 1;
    });
    let median = wave.median;
    let gen_tokens = (GENS_PER_WAVE * max_new) as f64;
    let gen_tok_s = gen_tokens / median.as_secs_f64().max(1e-12);
    println!(
        "  mixed wave: {GENS_PER_WAVE} generations x {max_new} new + {SCORES_PER_WAVE} scores \
         in {median:?} — {gen_tok_s:.0} generated tok/s under contention\n"
    );
    let metrics = server.shutdown();
    assert_eq!(metrics.generation_failures, 0, "saturation must not fail sequences");
    println!("{}", metrics.report(median));
    let summary = Json::obj(vec![
        ("bench", Json::str("paged_serve")),
        ("config", common::bench_config_json(&cfg)),
        (
            "sched",
            Json::obj(vec![
                ("page_size", Json::num(sched.page_size as f64)),
                ("kv_blocks", Json::num(sched.kv_blocks as f64)),
                ("prefill_chunk", Json::num(sched.prefill_chunk as f64)),
                ("max_batch", Json::num(b as f64)),
                ("seq", Json::num(s as f64)),
                ("gens_per_wave", Json::num(GENS_PER_WAVE as f64)),
                ("scores_per_wave", Json::num(SCORES_PER_WAVE as f64)),
                ("prompt_len", Json::num(prompt_len as f64)),
                ("max_new", Json::num(max_new as f64)),
            ]),
        ),
        (
            "results",
            Json::obj(vec![
                ("generated_tok_s", Json::num(gen_tok_s)),
                ("wave_p50_us", Json::num(common::us(wave.median))),
                ("wave_p99_us", Json::num(common::us(wave.p99))),
                ("request_p50_us", Json::num(common::us(metrics.request_latency.quantile(0.5)))),
                ("request_p99_us", Json::num(common::us(metrics.request_latency.quantile(0.99)))),
                ("requests", Json::num(metrics.request_latency.count() as f64)),
                ("preemptions", Json::num(metrics.preemptions as f64)),
                ("evicted_blocks", Json::num(metrics.evicted_blocks as f64)),
                ("recomputed_tokens", Json::num(metrics.recomputed_tokens as f64)),
            ]),
        ),
    ]);
    common::write_bench_json("paged_serve", summary);
}
