//! Batched vs serial native forward throughput — the number the unified
//! execution backend exists to move. The serial loop is the pre-refactor
//! eval/serving path (one `DenseModel::forward` per sequence on one
//! thread); the batched path is `exec::NativeBackend` fanning the same
//! rows over its worker pool. Logits are bit-identical by construction
//! (asserted below before timing), so the speedup is free.
//!
//! No artifacts needed: runs on the synthetic checkpoint, fp and a
//! heterogeneous searched-plan quantized variant.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use gsr::exec::{Backend, NativeBackend};
use gsr::model::{DenseModel, FpParams, ModelCfg, R4Kind};
use gsr::quant::{build_plan_rotations, quantize_native_plan, RotationPlan, RotationSpec};
use gsr::transform::R1Kind;

fn bench_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 256,
        d_model: 128,
        n_layers: 4,
        n_heads: 4,
        d_ffn: 256,
        group: 64,
        rope_base: 10_000.0,
        norm_eps: 1e-5,
    }
}

fn hetero_plan(cfg: &ModelCfg) -> RotationPlan {
    let base = RotationSpec::baseline(cfg);
    let mut layers = vec![base; cfg.n_layers];
    layers[1] = RotationSpec { r1: R1Kind::LH, r1_block: 32, r4: R4Kind::LH, r4_block: 64 };
    RotationPlan { seed: 2025, layers }
}

fn bench_model(label: &str, model: Arc<DenseModel>, batch: usize, seq: usize) {
    let vocab = model.cfg().vocab;
    let tokens: Vec<i32> = (0..batch * seq).map(|i| ((i * 7 + 1) % vocab) as i32).collect();

    // Correctness first: batched rows must be bit-identical to serial.
    let backend = NativeBackend::new(Arc::clone(&model), batch, seq, 0);
    let batched_out = backend.forward_batch(&tokens).expect("batched forward");
    for row in 0..batch {
        let serial = model.forward(&tokens[row * seq..(row + 1) * seq]);
        let got = &batched_out[row * seq * vocab..(row + 1) * seq * vocab];
        for (a, b) in got.iter().zip(&serial) {
            assert_eq!(a.to_bits(), b.to_bits(), "batched forward diverged from serial");
        }
    }

    let n_tokens = (batch * seq) as f64;
    let serial = common::time_it(&format!("serial  fwd {label} b={batch}"), 1, 3, || {
        let mut last = 0f32;
        for row in 0..batch {
            let out = model.forward(&tokens[row * seq..(row + 1) * seq]);
            last = out[0];
        }
        last
    });
    let batched = common::time_it(&format!("batched fwd {label} b={batch}"), 1, 3, || {
        backend.forward_batch(&tokens).unwrap()
    });
    let tok_s = |d: std::time::Duration| n_tokens / d.as_secs_f64().max(1e-12);
    println!(
        "  {label} b={batch}: serial {:.0} tok/s, batched {:.0} tok/s — {:.2}x speedup\n",
        tok_s(serial),
        tok_s(batched),
        serial.as_secs_f64() / batched.as_secs_f64().max(1e-12),
    );
}

fn main() {
    let cfg = bench_cfg();
    let fp = FpParams::synthetic(&cfg, 7);
    let fp_model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() });
    let rots = build_plan_rotations(&cfg, &hetero_plan(&cfg)).unwrap();
    let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
    let plan_model = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None });
    let seq = 64;
    for batch in [4usize, 8] {
        bench_model("fp       ", Arc::clone(&fp_model), batch, seq);
    }
    for batch in [4usize, 8] {
        bench_model("searched ", Arc::clone(&plan_model), batch, seq);
    }
}
