//! Batched vs serial native forward throughput — the number the unified
//! execution backend exists to move. The serial loop is the pre-refactor
//! eval/serving path (one `DenseModel::forward` per sequence on one
//! thread); the batched path is `exec::NativeBackend` fanning the same
//! rows over its worker pool. Logits are bit-identical by construction
//! (asserted below before timing), so the speedup is free.
//!
//! No artifacts needed: runs on the synthetic checkpoint, fp and a
//! heterogeneous searched-plan quantized variant.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use gsr::config::Json;
use gsr::exec::{Backend, NativeBackend};
use gsr::model::{DenseModel, FpParams};
use gsr::quant::{build_plan_rotations, quantize_native_plan};

fn bench_model(label: &str, model: Arc<DenseModel>, batch: usize, seq: usize) -> Json {
    let vocab = model.cfg().vocab;
    let tokens: Vec<i32> = (0..batch * seq).map(|i| ((i * 7 + 1) % vocab) as i32).collect();

    // Correctness first: batched rows must be bit-identical to serial.
    let backend = NativeBackend::new(Arc::clone(&model), batch, seq, 0);
    let batched_out = backend.forward_batch(&tokens).expect("batched forward");
    for row in 0..batch {
        let serial = model.forward(&tokens[row * seq..(row + 1) * seq]);
        let got = &batched_out[row * seq * vocab..(row + 1) * seq * vocab];
        for (a, b) in got.iter().zip(&serial) {
            assert_eq!(a.to_bits(), b.to_bits(), "batched forward diverged from serial");
        }
    }

    let n_tokens = (batch * seq) as f64;
    let serial = common::time_stats(&format!("serial  fwd {label} b={batch}"), 1, 3, || {
        let mut last = 0f32;
        for row in 0..batch {
            let out = model.forward(&tokens[row * seq..(row + 1) * seq]);
            last = out[0];
        }
        last
    });
    let batched = common::time_stats(&format!("batched fwd {label} b={batch}"), 1, 3, || {
        backend.forward_batch(&tokens).unwrap()
    });
    let tok_s = |d: std::time::Duration| n_tokens / d.as_secs_f64().max(1e-12);
    println!(
        "  {label} b={batch}: serial {:.0} tok/s, batched {:.0} tok/s — {:.2}x speedup\n",
        tok_s(serial.median),
        tok_s(batched.median),
        serial.median.as_secs_f64() / batched.median.as_secs_f64().max(1e-12),
    );
    Json::obj(vec![
        ("variant", Json::str(label.trim())),
        ("batch", Json::num(batch as f64)),
        ("seq", Json::num(seq as f64)),
        ("serial_tok_s", Json::num(tok_s(serial.median))),
        ("batched_tok_s", Json::num(tok_s(batched.median))),
        ("batched_p50_us", Json::num(common::us(batched.median))),
        ("batched_p99_us", Json::num(common::us(batched.p99))),
    ])
}

fn main() {
    let cfg = common::bench_model_cfg();
    let fp = FpParams::synthetic(&cfg, 7);
    let fp_model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() });
    let rots = build_plan_rotations(&cfg, &common::bench_hetero_plan(&cfg)).unwrap();
    let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
    let plan_model = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None });
    let seq = 64;
    let mut results = Vec::new();
    for batch in [4usize, 8] {
        results.push(bench_model("fp       ", Arc::clone(&fp_model), batch, seq));
    }
    for batch in [4usize, 8] {
        results.push(bench_model("searched ", Arc::clone(&plan_model), batch, seq));
    }
    let summary = Json::obj(vec![
        ("bench", Json::str("serve_throughput")),
        ("config", common::bench_config_json(&cfg)),
        ("results", Json::Arr(results)),
    ]);
    common::write_bench_json("serve_throughput", summary);
}
