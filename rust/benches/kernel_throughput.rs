//! Packed fused kernels vs the dense reference path — the numbers the
//! `--kernels fast` layer exists to move.
//!
//! Two comparisons, parity asserted before any timing:
//!
//! * **Fused dequant-matmul** (int2 and int4): consuming the packed
//!   bytes directly, one cache-hot tile at a time, against (a) the
//!   honest baseline of dequantize-to-f32 then dense matmul per call,
//!   and (b) the resident-dense f64-accumulation matmul the reference
//!   forward actually runs (weights pre-dequantized once).
//! * **Structured rotation**: FWHT + sequency permutation through
//!   [`R1Desc`] against the dense `[n, n]` rotation matmul.
//!
//! No artifacts needed; shapes follow the serving bench geometry.

#[path = "common/mod.rs"]
mod common;

use gsr::model::forward::matmul;
use gsr::model::{packed_matmul_into, PackedLinear, R1Desc};
use gsr::rng::SplitMix64;
use gsr::transform::{walsh, R1Kind};

fn assert_close(fast: &[f32], reference: &[f32], tol: f32, what: &str) {
    assert_eq!(fast.len(), reference.len(), "{what}: length");
    for (a, b) in fast.iter().zip(reference) {
        assert!(
            (a - b).abs() <= tol * b.abs().max(1.0),
            "{what}: parity failed before timing ({a} vs {b})"
        );
    }
}

fn bench_packed(bits: u32) {
    let (t, c, h, group) = (32usize, 512usize, 512usize, 64usize);
    let mut rng = SplitMix64::new(0xBE << bits);
    let qmax = (1u64 << bits) - 1;
    let codes: Vec<i32> = (0..c * h).map(|_| rng.next_below(qmax + 1) as i32).collect();
    let ng = c / group;
    let scale: Vec<f32> = (0..ng * h).map(|_| 0.01 + rng.next_f64() as f32 * 0.05).collect();
    let zero: Vec<f32> = (0..ng * h).map(|_| rng.next_below(qmax + 1) as f32).collect();
    let w = PackedLinear::from_codes(&codes, c, h, group, scale, zero, bits).unwrap();
    let x: Vec<f32> = (0..t * c).map(|_| rng.next_normal() as f32).collect();

    let resident = w.dequant_dense();
    let want = matmul(&x, &resident, t, c, h);
    let (mut out, mut acc) = (Vec::new(), Vec::new());
    packed_matmul_into(&x, &w, t, &mut out, &mut acc);
    assert_close(&out, &want, 1e-4, &format!("int{bits} fused matmul"));

    let dequant = common::time_it(&format!("int{bits} dequant-to-f32 + dense matmul"), 2, 7, || {
        matmul(&x, &w.dequant_dense(), t, c, h)
    });
    let dense = common::time_it(&format!("int{bits} resident dense matmul (f64 acc)"), 2, 7, || {
        matmul(&x, &resident, t, c, h)
    });
    let fused = common::time_it(&format!("int{bits} packed fused matmul"), 2, 7, || {
        packed_matmul_into(&x, &w, t, &mut out, &mut acc);
        out.len()
    });
    println!(
        "  int{bits} [{t}x{c}]@[{c}x{h}]: fused {:.2}x vs dequant-to-f32, {:.2}x vs resident \
         dense\n",
        dequant.as_secs_f64() / fused.as_secs_f64().max(1e-12),
        dense.as_secs_f64() / fused.as_secs_f64().max(1e-12),
    );
    assert!(
        fused < dequant,
        "int{bits}: the fused kernel must beat the dequant-to-f32 baseline \
         ({fused:?} vs {dequant:?})"
    );
}

fn bench_rotation() {
    let (rows, n) = (256usize, 256usize);
    let w = walsh(n);
    let desc = R1Desc::from_mat(R1Kind::GW, n, &w).expect("walsh recognized");
    let dense: Vec<f32> = w.data.iter().map(|&v| v as f32).collect();
    let mut rng = SplitMix64::new(0x40);
    let x: Vec<f32> = (0..rows * n).map(|_| rng.next_normal() as f32).collect();

    let want = matmul(&x, &dense, rows, n, n);
    let mut got = x.clone();
    let mut tmp = Vec::new();
    desc.forward_rows(&mut got, &mut tmp);
    assert_close(&got, &want, 1e-3, "fwht rotation");

    let dense_t = common::time_it("rotation dense matmul [256, 256x256]", 2, 7, || {
        matmul(&x, &dense, rows, n, n)
    });
    let fwht_t = common::time_it("rotation fwht + sequency perm        ", 2, 7, || {
        let mut y = x.clone();
        desc.forward_rows(&mut y, &mut tmp);
        y.len()
    });
    println!(
        "  rotation [{rows}x{n}]: fwht {:.2}x vs dense matmul\n",
        dense_t.as_secs_f64() / fwht_t.as_secs_f64().max(1e-12),
    );
}

fn main() {
    bench_packed(2);
    bench_packed(4);
    bench_rotation();
}
