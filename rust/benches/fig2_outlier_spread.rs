//! Quantifies the paper's **Fig. 2**: global rotation spreads an
//! outlier's energy across all channels (participation ratio ≈ n),
//! local rotation confines it to its block (PR ≈ G, in-group energy 1).
//! Also sweeps block size to show the containment/mixing trade-off.

#[path = "common/mod.rs"]
mod common;

use gsr::analysis::outlier_spread;
use gsr::transform::{block_diag, walsh};

fn main() {
    println!("Fig. 2 quantified — outlier energy spread by rotation kind");
    for (n, group) in [(256usize, 64usize), (512, 64)] {
        println!("--- n={n} group={group} ---");
        println!("{:6} {:>20} {:>18}", "R1", "participation ratio", "in-group energy");
        for s in outlier_spread(n, group, 11) {
            println!(
                "{:6} {:>20.1} {:>18.3}",
                s.kind.to_string(),
                s.participation_ratio,
                s.in_group_energy
            );
        }
    }
    println!("\nBlock-size sweep (Walsh blocks, n=512):");
    println!("{:>8} {:>20} {:>18}", "G", "participation ratio", "in-group energy");
    for g in [16usize, 32, 64, 128, 256, 512] {
        let r = block_diag(&walsh(g), 512);
        let (pr, ig) = gsr::analysis::outliers::spread_of(&r, g);
        println!("{g:>8} {pr:>20.1} {ig:>18.3}");
    }
    common::time_it("outlier_spread(512,64)", 1, 5, || outlier_spread(512, 64, 11));
}
