//! The headline win of the `gsr search` subsystem: a searched per-layer
//! rotation plan vs the fixed global-GSR configuration, on measured
//! group-RTN proxy error *and* on end-to-end identity-Hessian GPTQ
//! weight SSE. Pure native (no PJRT, no artifacts) — the checkpoint is
//! the structured synthetic one `gsr search --synthetic` uses, whose
//! outlier channels move per layer so one fixed block size cannot be
//! optimal everywhere.

#[path = "common/mod.rs"]
mod common;

use gsr::eval::tables::{plan_summary, search_table};
use gsr::model::{FpParams, ModelCfg};
use gsr::quant::{build_plan_rotations, quantize_native_plan, RotationPlan, RotationSpec};
use gsr::search::{search_plan, SearchCfg};

fn main() {
    let cfg = ModelCfg::default();
    println!(
        "search-plan bench — d={} layers={} ffn={} group={}",
        cfg.d_model, cfg.n_layers, cfg.d_ffn, cfg.group
    );
    let fp = FpParams::synthetic(&cfg, 2025);
    let scfg = SearchCfg::default();

    let t0 = std::time::Instant::now();
    let outcome = search_plan(&fp, &cfg, &scfg).expect("search");
    println!("{}", search_table(&outcome).render());
    println!(
        "search wall {:?}; {} layer(s) strictly improved; mean MSE {:.4e} vs baseline {:.4e}\n",
        t0.elapsed(),
        outcome.improved_layers(),
        outcome.mean_mse(),
        outcome.mean_baseline_mse()
    );

    // End-to-end check: does the proxy win survive GPTQ?
    let baseline = RotationPlan::uniform(RotationSpec::baseline(&cfg), cfg.n_layers, scfg.seed);
    let mut sses = Vec::new();
    for (name, plan) in [("fixed-GSR", &baseline), ("searched", &outcome.plan)] {
        let rots = build_plan_rotations(&cfg, plan).expect("build rotations");
        let (_qp, sse, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
        println!(
            "{name:10} GPTQ weight SSE {sse:10.3}   {}",
            plan_summary(plan)
        );
        sses.push(sse);
    }
    println!(
        "searched/fixed SSE ratio: {:.4} (< 1 means the searched plan wins end-to-end)\n",
        sses[1] / sses[0]
    );

    common::time_it("search_plan(default grid)", 0, 3, || {
        search_plan(&fp, &cfg, &scfg).unwrap()
    });
}
