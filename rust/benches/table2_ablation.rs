//! Regenerates the paper's **Table 2**: the R4 local-rotation ablation
//! (QuaRot; R1 ∈ {LH, GSR} × R4 ∈ {GH, LH}; PPL under W2 and W2A4).
//!
//! Expected shape (paper A.2): switching R4 GH→LH helps under activation
//! quantization (W2A4 column) and is ~neutral for weight-only (W2).

#[path = "common/mod.rs"]
mod common;

use std::path::Path;

fn main() {
    if !common::require_artifacts() {
        return;
    }
    let mut opts = common::eval_opts();
    opts.tasks_per_kind = 0; // Table 2 is PPL-only
    match gsr::eval::tables::table2(Path::new("artifacts"), opts) {
        Ok(table) => {
            println!("{}", table.render());
            println!("Paper reference (Llama-2-7B): LH/GH 12.11|17.74, LH/LH 12.65|14.64,");
            println!("                              GSR/GH 11.59|15.23, GSR/LH 11.22|13.83");
        }
        Err(e) => println!("table2 failed: {e}"),
    }
}
