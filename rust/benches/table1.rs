//! Regenerates the paper's **Table 1**: PPL + averaged zero-shot accuracy
//! for {QuaRot, SpinQuant, OSTQuant} × {W2A16, W2A4} × R1 ∈ {GH, GW, LH,
//! GSR}, over the AOT artifacts through the PJRT runtime.
//!
//! Success criterion is the *shape*, not absolute numbers (the host is a
//! 3M-param byte model on a synthetic corpus — DESIGN.md §2): within
//! each method/bits block, PPL should order GH ≥ GW ≥ LH ≥ GSR and
//! accuracy the reverse; GSR-on-QuaRot should approach the learned
//! pipelines. Paper reference values are printed alongside.

#[path = "common/mod.rs"]
mod common;

use std::path::Path;

fn main() {
    if !common::require_artifacts() {
        return;
    }
    let opts = common::eval_opts();
    let t0 = std::time::Instant::now();
    match gsr::eval::tables::table1(Path::new("artifacts"), opts, true) {
        Ok(table) => {
            println!("{}", table.render());
            println!("(eval opts: {opts:?}, wall {:?})", t0.elapsed());
            println!();
            println!("Paper reference (Llama-2-7B, WikiText-2) for shape comparison:");
            println!("  QuaRot    W2A16: GH 20.29 / GW 15.38 / LH 12.11 / GSR 11.59");
            println!("  QuaRot    W2A4 : GH 31.33 / GW 20.34 / LH 17.74 / GSR 15.23");
            println!("  SpinQuant W2A16: GH 16.45 / GW 16.44 / LH 13.17 / GSR 12.04");
            println!("  OSTQuant  W2A16: GH 10.97 / GW  9.51 / LH  9.16 / GSR  9.03");
        }
        Err(e) => println!("table1 failed: {e}"),
    }
}
