//! Cached vs full-reforward decoding throughput — the number the
//! incremental decoding engine exists to move. The "reforward" loop is
//! the only generation strategy the pre-KV-cache engine could offer:
//! every emitted token re-runs the whole `[1, prefix]` forward, so a
//! decode of `n` tokens from a `p`-token prompt costs `O((p + n)²)`
//! linears. The cached path (`Backend::start_generation` + `decode`)
//! prefills once and then pays `O(1)` linears per token, with the
//! decode-step matmuls column-sharded and attention head-sharded across
//! the ExecPool workers.
//!
//! Logits are bit-identical by construction (asserted below before any
//! timing), so the speedup is free — same tokens, fewer FLOPs.
//!
//! No artifacts needed: runs on the synthetic checkpoint, fp and a
//! heterogeneous searched-plan quantized variant.

#[path = "common/mod.rs"]
mod common;

use std::sync::Arc;

use gsr::config::Json;
use gsr::exec::{greedy_argmax, Backend, NativeBackend};
use gsr::model::{DenseModel, FpParams};
use gsr::quant::{build_plan_rotations, quantize_native_plan};

/// Greedy decode by full re-forward of the growing prefix (the
/// pre-cache strategy). Returns the emitted tokens.
fn reforward_decode(model: &DenseModel, prompt: &[i32], new_tokens: usize) -> Vec<i32> {
    let v = model.cfg().vocab;
    let mut seq = prompt.to_vec();
    let mut out = Vec::with_capacity(new_tokens);
    for _ in 0..new_tokens {
        let logits = model.forward(&seq);
        let tok = greedy_argmax(&logits[(seq.len() - 1) * v..]);
        out.push(tok);
        seq.push(tok);
    }
    out
}

/// Greedy decode through the KV-cached generation contract.
fn cached_decode(backend: &NativeBackend, prompt: &[i32], new_tokens: usize) -> Vec<i32> {
    let (mut gen, last) = backend.start_generation(prompt).expect("prefill");
    let mut out = Vec::with_capacity(new_tokens);
    let mut tok = greedy_argmax(&last);
    out.push(tok);
    for _ in 1..new_tokens {
        let logits = backend.decode(&mut gen, tok).expect("decode");
        tok = greedy_argmax(&logits);
        out.push(tok);
    }
    out
}

fn bench_model(label: &str, model: Arc<DenseModel>, prompt_len: usize, new_tokens: usize) -> Json {
    let vocab = model.cfg().vocab;
    let capacity = prompt_len + new_tokens;
    let prompt: Vec<i32> = (0..prompt_len).map(|i| ((i * 7 + 1) % vocab) as i32).collect();
    let backend = NativeBackend::new(Arc::clone(&model), 1, capacity, 0);

    // Correctness first: every cached step must be bit-identical to the
    // full re-forward of the same prefix (token equality follows, but
    // assert the logits directly at each step).
    {
        let (mut gen, last) = backend.start_generation(&prompt).expect("prefill");
        let mut prefix = prompt.clone();
        let full = model.forward(&prefix);
        for (a, b) in last.iter().zip(&full[(prefix.len() - 1) * vocab..]) {
            assert_eq!(a.to_bits(), b.to_bits(), "prefill logits diverged");
        }
        let mut tok = greedy_argmax(&last);
        for _ in 1..new_tokens {
            prefix.push(tok);
            let got = backend.decode(&mut gen, tok).expect("decode");
            let full = model.forward(&prefix);
            for (a, b) in got.iter().zip(&full[(prefix.len() - 1) * vocab..]) {
                assert_eq!(a.to_bits(), b.to_bits(), "cached decode diverged from reforward");
            }
            tok = greedy_argmax(&got);
        }
    }

    let reforward = common::time_stats(
        &format!("reforward decode {label} p={prompt_len}"),
        1,
        3,
        || reforward_decode(&model, &prompt, new_tokens),
    );
    let cached = common::time_stats(
        &format!("cached    decode {label} p={prompt_len}"),
        1,
        3,
        || cached_decode(&backend, &prompt, new_tokens),
    );
    let tok_s = |d: std::time::Duration| new_tokens as f64 / d.as_secs_f64().max(1e-12);
    println!(
        "  {label} p={prompt_len} n={new_tokens}: reforward {:.0} tok/s, cached {:.0} tok/s — \
         {:.2}x speedup\n",
        tok_s(reforward.median),
        tok_s(cached.median),
        reforward.median.as_secs_f64() / cached.median.as_secs_f64().max(1e-12),
    );
    Json::obj(vec![
        ("variant", Json::str(label.trim())),
        ("prompt_len", Json::num(prompt_len as f64)),
        ("new_tokens", Json::num(new_tokens as f64)),
        ("reforward_tok_s", Json::num(tok_s(reforward.median))),
        ("cached_tok_s", Json::num(tok_s(cached.median))),
        ("cached_p50_us", Json::num(common::us(cached.median))),
        ("cached_p99_us", Json::num(common::us(cached.p99))),
    ])
}

fn main() {
    let cfg = common::bench_model_cfg();
    let fp = FpParams::synthetic(&cfg, 7);
    let fp_model = Arc::new(DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() });
    let rots = build_plan_rotations(&cfg, &common::bench_hetero_plan(&cfg)).unwrap();
    let (qp, _, _) = quantize_native_plan(&fp, &cfg, &rots, 2);
    let plan_model = Arc::new(DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None });
    let new_tokens = 32;
    let mut results = Vec::new();
    // The acceptance sweep: cached decode must win from seq >= 64.
    for prompt_len in [64usize, 96] {
        results.push(bench_model("fp       ", Arc::clone(&fp_model), prompt_len, new_tokens));
    }
    for prompt_len in [64usize, 96] {
        results.push(bench_model("searched ", Arc::clone(&plan_model), prompt_len, new_tokens));
    }
    let summary = Json::obj(vec![
        ("bench", Json::str("decode_throughput")),
        ("config", common::bench_config_json(&cfg)),
        ("results", Json::Arr(results)),
    ]);
    common::write_bench_json("decode_throughput", summary);
}
