//! The "for free" claim at the systems level: fast-Hadamard butterflies
//! are O(n log n) vs O(n²) dense rotation matmuls, and the *grouped*
//! (GSR/local) transform is cheaper still — the inverse of the paper's
//! Appendix-A.2 GPU limitation (DESIGN.md §5).

#[path = "common/mod.rs"]
mod common;

use gsr::rng::SplitMix64;
use gsr::transform::{build_r1, fwht_batch, grouped_fwht_batch, Mat, R1Kind};

/// The pre-PR 3 `Mat::matmul` (straight ikj walk, no tiling) — kept here
/// as the reference the cache-blocked fast path is measured against.
fn naive_matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for k in 0..a.cols {
            let av = a[(i, k)];
            if av == 0.0 {
                continue;
            }
            let brow = b.row(k);
            let orow = out.row_mut(i);
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    out
}

/// Dense matmul: blocked fast path vs the naive reference. These sizes
/// bracket the products that dominate `gsr search` (`R1ᵀ · stream` at
/// `d × (3d + 2f)`) and `gsr calibrate` (`R H Rᵀ` at `d_ffn × d_ffn`).
fn bench_matmul() {
    let mut rng = SplitMix64::new(7);
    // Correctness cross-check before timing anything.
    let a = Mat::from_fn(96, 80, |_, _| rng.next_normal());
    let b = Mat::from_fn(80, 112, |_, _| rng.next_normal());
    let (fast, slow) = (a.matmul(&b), naive_matmul(&a, &b));
    for (x, y) in fast.data.iter().zip(&slow.data) {
        assert!((x - y).abs() < 1e-10, "blocked matmul diverges from naive");
    }

    for n in [256usize, 512, 1024] {
        let a = Mat::from_fn(n, n, |_, _| rng.next_normal());
        let b = Mat::from_fn(n, n, |_, _| rng.next_normal());
        let naive = common::time_it(&format!("naive matmul   n={n}"), 1, 3, || {
            naive_matmul(&a, &b)
        });
        let blocked =
            common::time_it(&format!("blocked matmul n={n}"), 1, 3, || a.matmul(&b));
        println!(
            "  speedup: blocked {:.2}x over naive\n",
            naive.as_secs_f64() / blocked.as_secs_f64()
        );
    }
}

fn main() {
    bench_matmul();
    let rows = 256;
    for n in [256usize, 512, 1024, 2048] {
        let group = 64;
        let mut rng = SplitMix64::new(1);
        let base: Vec<f64> = (0..rows * n).map(|_| rng.next_normal()).collect();

        // Dense rotation matmul (what a non-Hadamard learned R1 costs).
        let r = build_r1(R1Kind::GH, n, group, &mut rng);
        let dense = common::time_it(&format!("dense x@R      n={n}"), 1, 5, || {
            let mut out = vec![0.0f64; rows * n];
            for row in 0..rows {
                let x = &base[row * n..(row + 1) * n];
                let o = &mut out[row * n..(row + 1) * n];
                for (k, &xv) in x.iter().enumerate() {
                    let rrow = r.row(k);
                    for (ov, &rv) in o.iter_mut().zip(rrow) {
                        *ov += xv * rv;
                    }
                }
            }
            out
        });

        let fast = common::time_it(&format!("global FWHT    n={n}"), 1, 10, || {
            let mut x = base.clone();
            fwht_batch(&mut x, n);
            x
        });

        let grouped = common::time_it(&format!("grouped FWHT   n={n} G={group}"), 1, 10, || {
            let mut x = base.clone();
            grouped_fwht_batch(&mut x, n, group);
            x
        });

        println!(
            "  speedup: FWHT {:.1}× over dense, grouped {:.1}× over dense, grouped {:.2}× over global\n",
            dense.as_secs_f64() / fast.as_secs_f64(),
            dense.as_secs_f64() / grouped.as_secs_f64(),
            fast.as_secs_f64() / grouped.as_secs_f64(),
        );
    }
}
