//! The "for free" claim at the systems level: fast-Hadamard butterflies
//! are O(n log n) vs O(n²) dense rotation matmuls, and the *grouped*
//! (GSR/local) transform is cheaper still — the inverse of the paper's
//! Appendix-A.2 GPU limitation (DESIGN.md §5).

#[path = "common/mod.rs"]
mod common;

use gsr::rng::SplitMix64;
use gsr::transform::{build_r1, fwht_batch, grouped_fwht_batch, R1Kind};

fn main() {
    let rows = 256;
    for n in [256usize, 512, 1024, 2048] {
        let group = 64;
        let mut rng = SplitMix64::new(1);
        let base: Vec<f64> = (0..rows * n).map(|_| rng.next_normal()).collect();

        // Dense rotation matmul (what a non-Hadamard learned R1 costs).
        let r = build_r1(R1Kind::GH, n, group, &mut rng);
        let dense = common::time_it(&format!("dense x@R      n={n}"), 1, 5, || {
            let mut out = vec![0.0f64; rows * n];
            for row in 0..rows {
                let x = &base[row * n..(row + 1) * n];
                let o = &mut out[row * n..(row + 1) * n];
                for (k, &xv) in x.iter().enumerate() {
                    let rrow = r.row(k);
                    for (ov, &rv) in o.iter_mut().zip(rrow) {
                        *ov += xv * rv;
                    }
                }
            }
            out
        });

        let fast = common::time_it(&format!("global FWHT    n={n}"), 1, 10, || {
            let mut x = base.clone();
            fwht_batch(&mut x, n);
            x
        });

        let grouped = common::time_it(&format!("grouped FWHT   n={n} G={group}"), 1, 10, || {
            let mut x = base.clone();
            grouped_fwht_batch(&mut x, n, group);
            x
        });

        println!(
            "  speedup: FWHT {:.1}× over dense, grouped {:.1}× over dense, grouped {:.2}× over global\n",
            dense.as_secs_f64() / fast.as_secs_f64(),
            dense.as_secs_f64() / grouped.as_secs_f64(),
            fast.as_secs_f64() / grouped.as_secs_f64(),
        );
    }
}
