//! Regenerates the paper's **Tables 3 & 4**: per-task zero-shot accuracy
//! breakdown (QuaRot & SpinQuant in Table 3; OSTQuant in Table 4), over
//! the synthetic task suite that stands in for lm-eval (DESIGN.md §2).

#[path = "common/mod.rs"]
mod common;

use std::path::Path;

fn main() {
    if !common::require_artifacts() {
        return;
    }
    let opts = common::eval_opts();
    let methods: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let methods = if methods.is_empty() {
        vec!["quarot".to_string(), "spinquant".to_string(), "ostquant".to_string()]
    } else {
        methods
    };
    for method in methods {
        match gsr::eval::tables::table3(Path::new("artifacts"), &method, opts) {
            Ok(table) => println!("{}", table.render()),
            Err(e) => println!("table3 ({method}) failed: {e}"),
        }
    }
}
