//! Self-speculative decoding throughput: a W2 draft of the same
//! checkpoint proposes k greedy tokens per round and the fp target
//! verifies them in one cached forward (`k + 1` logit rows), so each
//! accepted draft saves a full target decode step.
//!
//! Parity is asserted before any timing — greedy *and* seeded-sampling
//! generations through the speculative executor must equal the
//! non-speculative target decode token for token (and the greedy ones
//! must equal a full re-forward of the growing prefix). The tok/s
//! numbers below are for bit-reproducible speculation, never for
//! drifted outputs.
//!
//! No artifacts needed: runs on the synthetic checkpoint.

#[path = "common/mod.rs"]
mod common;

use std::sync::{mpsc, Arc};

use gsr::config::Json;
use gsr::coordinator::{BatchPolicy, GenerateRequest, Server};
use gsr::exec::{greedy_argmax, ExecPool, NativeBackend, NativeSet};
use gsr::model::{DenseModel, FpParams, ModelCfg};
use gsr::quant::{build_plan_rotations, quantize_native_plan};
use gsr::sched::{SamplingParams, SchedConfig, SpecConfig};

/// Generations per timed wave (half greedy, half sampled).
const GENS_PER_WAVE: usize = 8;
/// Draft tokens proposed per speculative round.
const SPEC_K: usize = 4;

/// Greedy decode by full re-forward of the growing prefix — the
/// reference semantics both serving paths must reproduce exactly.
fn reforward_greedy(model: &DenseModel, prompt: &[i32], max_new: usize) -> Vec<i32> {
    let v = model.cfg().vocab;
    let mut seq = prompt.to_vec();
    let mut out = Vec::with_capacity(max_new);
    for _ in 0..max_new {
        let logits = model.forward(&seq);
        let tok = greedy_argmax(&logits[(seq.len() - 1) * v..]);
        out.push(tok);
        seq.push(tok);
    }
    out
}

fn prompt_for(i: usize, len: usize, vocab: usize) -> Vec<i32> {
    (0..len).map(|j| ((j * 11 + i * 29 + 3) % vocab) as i32).collect()
}

fn sampling_for(i: usize) -> SamplingParams {
    if i % 2 == 0 {
        SamplingParams::greedy()
    } else {
        SamplingParams { temperature: 0.8, top_k: 32, top_p: 0.95, seed: i as u64 }
    }
}

/// Build the two-variant set — fp target plus a W2 quantized draft of
/// the same checkpoint — and start a server over it.
fn start_server(
    cfg: &ModelCfg,
    fp: &FpParams,
    batch: usize,
    seq: usize,
    sched: SchedConfig,
) -> Server {
    let rots = build_plan_rotations(cfg, &common::bench_hetero_plan(cfg)).unwrap();
    let (qp, _, _) = quantize_native_plan(fp, cfg, &rots, 2);
    let pool = Arc::new(ExecPool::new(0));
    let mut set = NativeSet::new();
    let fp_model = DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() };
    let q2_model = DenseModel::Quant { cfg: cfg.clone(), params: qp, a_bits: None };
    set.insert("fp", NativeBackend::with_pool(Arc::new(fp_model), batch, seq, Arc::clone(&pool)));
    set.insert("q2", NativeBackend::with_pool(Arc::new(q2_model), batch, seq, pool));
    let policy = BatchPolicy { max_batch: batch, ..BatchPolicy::default() };
    Server::start_native_sched(set, policy, sched).expect("server start")
}

/// One timed wave: submit every generation up front (continuous
/// batching keeps the rounds full), drain every reply, return the
/// emitted sequences.
fn run_wave(
    server: &Server,
    cfg: &ModelCfg,
    wave_idx: usize,
    prompt_len: usize,
    max_new: usize,
) -> Vec<Vec<i32>> {
    let mut pending = Vec::new();
    for i in 0..GENS_PER_WAVE {
        let (reply, rx) = mpsc::channel();
        server
            .submit_generate(GenerateRequest {
                variant: "fp".to_string(),
                prompt: prompt_for(wave_idx * 64 + i, prompt_len, cfg.vocab),
                max_new,
                stop: None,
                sampling: sampling_for(i),
                stream: None,
                reply,
            })
            .expect("submit generate");
        pending.push(rx);
    }
    pending
        .into_iter()
        .map(|rx| rx.recv().expect("reply").result.expect("generation").tokens)
        .collect()
}

fn main() {
    let cfg = common::bench_model_cfg();
    let fp = FpParams::synthetic(&cfg, 7);
    let model = DenseModel::Fp { cfg: cfg.clone(), params: fp.clone() };
    let (batch, seq) = (4usize, 96usize);
    let sched = SchedConfig { page_size: 16, kv_blocks: 48, prefill_chunk: 32, speculate: None };
    let spec_sched = SchedConfig {
        speculate: Some(SpecConfig { draft: "q2".to_string(), k: SPEC_K }),
        ..sched.clone()
    };
    let baseline = start_server(&cfg, &fp, batch, seq, sched.clone());
    let spec = start_server(&cfg, &fp, batch, seq, spec_sched);
    let (prompt_len, max_new) = (48usize, 24usize);

    // Parity gate before any timing: speculative output must equal the
    // non-speculative target decode token for token, greedy and
    // sampled alike — and greedy must equal the full re-forward.
    let parity_cases = 6;
    for i in 0..parity_cases {
        let prompt = prompt_for(i, prompt_len, cfg.vocab);
        let sampling = sampling_for(i);
        let want = baseline
            .generate_with("fp", prompt.clone(), max_new, None, sampling.clone())
            .expect("baseline generation");
        let got = spec
            .generate_with("fp", prompt.clone(), max_new, None, sampling)
            .expect("speculative generation");
        assert_eq!(
            got.tokens, want.tokens,
            "speculative decode diverged from non-speculative (case {i})"
        );
        if i % 2 == 0 {
            let reforward = reforward_greedy(&model, &prompt, max_new);
            assert_eq!(got.tokens, reforward, "greedy diverged from re-forward (case {i})");
        }
    }
    println!(
        "parity: speculative == non-speculative on {parity_cases} cases (greedy + sampled)\n"
    );

    // Timed waves — identical traffic through both servers.
    let mut wi = 0usize;
    let base_wave = common::time_stats("baseline decode wave", 1, 3, || {
        run_wave(&baseline, &cfg, wi, prompt_len, max_new);
        wi += 1;
    });
    let mut wi = 0usize;
    let spec_wave = common::time_stats("speculative decode wave", 1, 3, || {
        run_wave(&spec, &cfg, wi, prompt_len, max_new);
        wi += 1;
    });
    let wave_tokens = (GENS_PER_WAVE * max_new) as f64;
    let base_tok_s = wave_tokens / base_wave.median.as_secs_f64().max(1e-12);
    let spec_tok_s = wave_tokens / spec_wave.median.as_secs_f64().max(1e-12);

    let base_metrics = baseline.shutdown();
    let spec_metrics = spec.shutdown();
    assert_eq!(spec_metrics.generation_failures, 0, "speculation must not fail sequences");
    assert!(spec_metrics.spec_rounds > 0, "speculative server ran no draft/verify rounds");
    let acceptance = spec_metrics.draft_acceptance();
    println!(
        "\n  wave of {GENS_PER_WAVE} x {max_new} tokens: baseline {base_tok_s:.0} tok/s, \
         speculative {spec_tok_s:.0} tok/s ({:.2}x); draft acceptance {:.1}% \
         ({} accepted / {} drafted over {} rounds)\n",
        spec_tok_s / base_tok_s.max(1e-12),
        100.0 * acceptance,
        spec_metrics.accepted_draft_tokens,
        spec_metrics.drafted_tokens,
        spec_metrics.spec_rounds,
    );
    println!("{}", spec_metrics.report(spec_wave.median));

    let summary = Json::obj(vec![
        ("bench", Json::str("spec_decode")),
        ("config", common::bench_config_json(&cfg)),
        (
            "sched",
            Json::obj(vec![
                ("page_size", Json::num(sched.page_size as f64)),
                ("kv_blocks", Json::num(sched.kv_blocks as f64)),
                ("prefill_chunk", Json::num(sched.prefill_chunk as f64)),
                ("spec_k", Json::num(SPEC_K as f64)),
                ("max_batch", Json::num(batch as f64)),
                ("gens_per_wave", Json::num(GENS_PER_WAVE as f64)),
                ("prompt_len", Json::num(prompt_len as f64)),
                ("max_new", Json::num(max_new as f64)),
            ]),
        ),
        (
            "results",
            Json::obj(vec![
                ("baseline_tok_s", Json::num(base_tok_s)),
                ("speculative_tok_s", Json::num(spec_tok_s)),
                ("speedup", Json::num(spec_tok_s / base_tok_s.max(1e-12))),
                ("draft_acceptance", Json::num(acceptance)),
                ("spec_rounds", Json::num(spec_metrics.spec_rounds as f64)),
                ("drafted_tokens", Json::num(spec_metrics.drafted_tokens as f64)),
                ("accepted_draft_tokens", Json::num(spec_metrics.accepted_draft_tokens as f64)),
                ("rejected_draft_tokens", Json::num(spec_metrics.rejected_draft_tokens as f64)),
                ("decode_emitted", Json::num(spec_metrics.decode_emitted as f64)),
                ("decode_tok_per_s", Json::num(spec_metrics.decode_tok_per_s())),
                ("baseline_decode_tok_per_s", Json::num(base_metrics.decode_tok_per_s())),
                ("wave_p50_us", Json::num(common::us(spec_wave.median))),
                ("wave_p99_us", Json::num(common::us(spec_wave.p99))),
            ]),
        ),
    ]);
    common::write_bench_json("spec_decode", summary);
}
