//! Quantifies the paper's §3.2 argument: the Walsh (sequency) ordering
//! lowers intra-group sequency variance of the front rotation's column
//! groups, which lowers group-quantization error on structured weights.
//! Pure native (no PJRT) — also times the analysis itself.

#[path = "common/mod.rs"]
mod common;

use gsr::analysis::sequency_variance_report;
use gsr::transform::R1Kind;

fn main() {
    for (n, group) in [(256usize, 64usize), (512, 64), (512, 128)] {
        println!("--- n={n} group={group} ---");
        let reports = sequency_variance_report(n, group, 64, 2, 7);
        println!(
            "{:6} {:>22} {:>26}",
            "R1", "mean seq. variance", "group-RTN MSE (struct W)"
        );
        for r in &reports {
            println!(
                "{:6} {:>22.2} {:>26.4e}",
                r.kind.to_string(),
                r.mean_group_variance,
                r.rotated_quant_mse
            );
        }
        let gh = reports.iter().find(|r| r.kind == R1Kind::GH).unwrap();
        let gw = reports.iter().find(|r| r.kind == R1Kind::GW).unwrap();
        println!(
            "GW/GH variance ratio: {:.3} (paper §3.2 predicts < 1)",
            gw.mean_group_variance / gh.mean_group_variance.max(1e-12)
        );
    }
    common::time_it("sequency_variance_report(256,64)", 1, 5, || {
        sequency_variance_report(256, 64, 64, 2, 7)
    });
}
