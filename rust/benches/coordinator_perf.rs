//! L3 coordinator performance: batcher/router micro-costs and, when
//! artifacts exist, end-to-end serving throughput under different batch
//! policies (the batching-policy knob tuned in EXPERIMENTS §Perf).

#[path = "common/mod.rs"]
mod common;

use std::path::Path;
use std::time::{Duration, Instant};

use gsr::coordinator::{BatchPolicy, DynamicBatcher, RoutePolicy, Router, Server};

fn micro() {
    common::time_it("batcher push+take x1024", 2, 20, || {
        let mut b = DynamicBatcher::new(BatchPolicy {
            max_batch: 4,
            max_wait: Duration::from_millis(1),
        });
        let mut n = 0usize;
        for i in 0..1024u64 {
            b.push(i);
            if b.len() >= 4 {
                n += b.take_batch().len();
            }
        }
        n
    });
    common::time_it("router route+complete x1024", 2, 20, || {
        let mut r = Router::new(RoutePolicy::LeastLoaded);
        for i in 0..8 {
            r.register(&format!("v{i}"));
        }
        for _ in 0..1024 {
            let v = r.route(None).unwrap();
            r.complete(&v);
        }
        r.total_in_flight()
    });
}

fn serving() {
    if !common::require_artifacts() {
        return;
    }
    let arts = gsr::runtime::Artifacts::load(Path::new("artifacts")).unwrap();
    let seq = arts.seq;
    let text = arts.test_split().to_vec();
    for (label, policy) in [
        ("batch=1 (no batching)", BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(1) }),
        ("batch=4 wait=2ms", BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(2) }),
        ("batch=4 wait=10ms", BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(10) }),
    ] {
        let server = match Server::start(Path::new("artifacts"), &["fp".to_string()], policy) {
            Ok(s) => s,
            Err(e) => {
                println!("server start failed: {e}");
                return;
            }
        };
        let n = 24;
        let t0 = Instant::now();
        // Submit asynchronously to give the batcher something to pack.
        let mut replies = Vec::new();
        for i in 0..n {
            let (tx, rx) = std::sync::mpsc::channel();
            let start = (i * 31) % (text.len() - seq - 1);
            let tokens: Vec<i32> = text[start..start + seq].iter().map(|&b| b as i32).collect();
            server
                .submit(gsr::coordinator::Request {
                    variant: "fp".to_string(),
                    tokens,
                    reply: tx,
                })
                .unwrap();
            replies.push(rx);
        }
        for rx in replies {
            rx.recv().unwrap().logits.unwrap();
        }
        let wall = t0.elapsed();
        let metrics = server.shutdown();
        println!("policy {label:22}: wall {wall:?} | {}", metrics.report(wall));
    }
}

fn main() {
    micro();
    serving();
}
